//! Container round-trip: `.tns → CooTensor → BlcoTensor → BlcoStore →
//! BlcoStoreReader → MTTKRP`, bit-for-bit equal to the resident path on
//! every mode and every executor (in-memory register/hierarchical,
//! single-device streamed, multi-device clustered, fused serving path),
//! with the block cache's peak residency provably under the host budget.
//! Plus the structured-error negative cases for corrupted containers.

use std::path::PathBuf;
use std::sync::Arc;

use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::coordinator::schedule::StreamSchedule;
use blco::device::{Counters, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::{BlcoStore, BlcoStoreReader, StoreError};
use blco::mttkrp::blco::{BlcoEngine, Resolution};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::mttkrp::Mttkrp;
use blco::service::TensorRegistry;
use blco::tensor::{io, synth};
use blco::StreamRequest;

fn tmpfile(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("blco_rt_{}_{}", std::process::id(), name));
    p
}

/// The full text → resident → container pipeline of this suite: write a
/// synthetic tensor as `.tns`, read it back, build BLCO with small blocks
/// (so streaming has a real pipeline), persist, reopen with `cache_budget`
/// bytes of host memory for the block cache.
fn build_container(
    name: &str,
    cache_budget: usize,
) -> (PathBuf, BlcoTensor, BlcoStoreReader) {
    let t = synth::fiber_clustered(&[60, 50, 40], 8_000, 2, 0.8, 3);
    let tns = tmpfile(&format!("{name}.tns"));
    io::write_tns(&tns, &t).unwrap();
    let back = io::read_tns(&tns, None).unwrap();
    std::fs::remove_file(&tns).ok();
    let cfg = BlcoConfig {
        max_block_nnz: 512,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    };
    let b = BlcoTensor::from_coo_with(&back, cfg);
    assert!(b.batches.len() > 4, "need a real batch pipeline");
    let path = tmpfile(&format!("{name}.blco"));
    BlcoStore::write(&b, &path).unwrap();
    let reader = BlcoStoreReader::open_with_budget(&path, cache_budget).unwrap();
    (path, b, reader)
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

// a budget of ~4 small blocks: full passes must evict
const TIGHT_BUDGET: usize = 4 * 512 * 16;

#[test]
fn in_memory_kernels_match_bit_for_bit_under_a_bounded_cache() {
    let (path, b, reader) = build_container("inmem", TIGHT_BUDGET);
    let dims = b.dims().to_vec();
    let t = b.to_coo();
    let factors = random_factors(&dims, 8, 5);
    let mut resident = BlcoEngine::new(b, Profile::a100());
    let mut disk = BlcoEngine::from_store_reader(reader, Profile::a100());
    for res in [Resolution::Register, Resolution::Hierarchical, Resolution::Auto] {
        resident.resolution = res;
        disk.resolution = res;
        for target in 0..dims.len() {
            let mut a = Matrix::zeros(dims[target] as usize, 8);
            let mut d = Matrix::zeros(dims[target] as usize, 8);
            // single-threaded: a fully deterministic float-op order, so
            // equality must hold to the bit, not to a tolerance
            resident.mttkrp(target, &factors, &mut a, 1, &Counters::new());
            disk.mttkrp(target, &factors, &mut d, 1, &Counters::new());
            assert_eq!(bits(&a), bits(&d), "{res:?} mode {target}");
            let expect = mttkrp_oracle(&t, target, &factors);
            assert!(a.max_abs_diff(&expect) < 1e-9, "{res:?} mode {target}");
        }
    }
    let stats = disk.src.reader().unwrap().cache_stats();
    assert!(
        stats.peak_resident_bytes <= TIGHT_BUDGET,
        "peak {} > budget {TIGHT_BUDGET}",
        stats.peak_resident_bytes
    );
    assert!(stats.evictions > 0, "the tight budget must force eviction");
    std::fs::remove_file(&path).ok();
}

#[test]
fn streamed_and_clustered_paths_match_bit_for_bit() {
    let (path, b, _reader) = build_container("stream", TIGHT_BUDGET);
    let dims = b.dims().to_vec();
    let factors = random_factors(&dims, 8, 7);
    // tiny device: every mode takes the out-of-memory path
    for devices in [1usize, 2, 4] {
        let prof = Profile::tiny(1 << 15).with_devices(devices);
        let resident = MttkrpEngine::from_blco(
            Arc::new(b.clone()),
            prof.clone(),
        )
        .with_threads(1);
        let disk = if devices == 1 {
            MttkrpEngine::from_source(
                blco::BatchSource::OnDisk(
                    BlcoStoreReader::open_with_budget(&path, TIGHT_BUDGET).unwrap(),
                ),
                prof.clone(),
            )
            .with_threads(1)
        } else {
            MttkrpEngine::from_store(&path, prof.clone())
                .unwrap()
                .with_threads(1)
        };
        for target in 0..dims.len() {
            assert!(resident.is_oom_for(target, 8), "tiny profile must stream");
            let (a, pa) = resident.mttkrp(target, &factors);
            let (d, pd) = disk.mttkrp(target, &factors);
            match (devices, &pa, &pd) {
                (1, ExecPath::Streamed(ra), ExecPath::Streamed(rd)) => {
                    // same plan, same modelled clock, same wire bytes
                    assert_eq!(ra.bytes, rd.bytes);
                    assert_eq!(ra.transfer_s, rd.transfer_s);
                    assert_eq!(ra.overall_s, rd.overall_s);
                }
                (_, ExecPath::Clustered(ra), ExecPath::Clustered(rd)) => {
                    assert_eq!(ra.devices, devices);
                    assert_eq!(ra.bytes, rd.bytes);
                    assert_eq!(ra.merge_bytes, rd.merge_bytes);
                    assert_eq!(ra.overall_s, rd.overall_s);
                }
                other => panic!("unexpected paths D={devices}: {other:?}"),
            }
            assert_eq!(bits(&a), bits(&d), "D={devices} mode {target}");
        }
        if let Some(stats) = disk.host_cache_stats() {
            assert!(stats.peak_resident_bytes <= TIGHT_BUDGET);
            assert!(stats.misses > 0, "streaming must read from disk");
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn fused_serving_path_matches_bit_for_bit_from_disk() {
    let (path, b, reader) = build_container("fused", TIGHT_BUDGET);
    let dims = b.dims().to_vec();
    let rank = 8;
    let seeds = [31u64, 37, 41];
    let factor_sets: Vec<Vec<Matrix>> =
        seeds.iter().map(|&s| random_factors(&dims, rank, s)).collect();
    let refs: Vec<&[Matrix]> = factor_sets.iter().map(|f| f.as_slice()).collect();

    let prof = Profile::tiny(1 << 15);
    let resident = BlcoEngine::new(b, prof.clone());
    let disk = BlcoEngine::from_store_reader(reader, prof);

    let sched_r = StreamSchedule::single_device(&resident, 0, rank);
    let sched_d = StreamSchedule::single_device(&disk, 0, rank);
    assert_eq!(sched_r.bytes, sched_d.bytes, "plans agree across tiers");
    assert_eq!(sched_r.transfer_s, sched_d.transfer_s);

    let mut outs_r: Vec<Matrix> =
        seeds.iter().map(|_| Matrix::zeros(dims[0] as usize, rank)).collect();
    let mut outs_d: Vec<Matrix> =
        seeds.iter().map(|_| Matrix::zeros(dims[0] as usize, rank)).collect();
    let ra = StreamRequest::new(&resident, 0)
        .fused(&refs)
        .schedule(&sched_r)
        .threads(1)
        .run(&mut outs_r)
        .unwrap()
        .into_streamed()
        .unwrap();
    let rd = StreamRequest::new(&disk, 0)
        .fused(&refs)
        .schedule(&sched_d)
        .threads(1)
        .run(&mut outs_d)
        .unwrap()
        .into_streamed()
        .unwrap();
    assert_eq!(ra.bytes, rd.bytes, "tensor crosses the wire once per tier");
    assert_eq!(ra.transfer_s, rd.transfer_s);
    for (a, d) in outs_r.iter().zip(&outs_d) {
        assert_eq!(bits(a), bits(d));
    }
    // one more single-job scheduled pass: the wrapper parity holds on disk
    let mut solo = Matrix::zeros(dims[0] as usize, rank);
    let rep = StreamRequest::new(&disk, 0)
        .job(refs[0])
        .schedule(&sched_d)
        .threads(1)
        .run(std::slice::from_mut(&mut solo))
        .unwrap()
        .into_streamed()
        .unwrap();
    assert_eq!(rep.bytes, ra.bytes);
    assert_eq!(bits(&solo), bits(&outs_r[0]));
    std::fs::remove_file(&path).ok();
}

#[test]
fn cpals_from_store_matches_resident_fit_trajectory() {
    let (path, b, _reader) = build_container("cpals", TIGHT_BUDGET);
    let prof = Profile::tiny(1 << 15);
    let opts = blco::cpals::CpAlsOptions {
        rank: 4,
        max_iters: 4,
        tol: 0.0,
        threads: 1,
        seed: 9,
    };
    let resident = MttkrpEngine::from_blco(Arc::new(b), prof.clone()).with_threads(1);
    let disk = MttkrpEngine::from_store(&path, prof).unwrap().with_threads(1);
    assert!((resident.norm_x - disk.norm_x).abs() < 1e-12, "header norm");
    let ra = resident.cp_als(opts);
    let rd = disk.cp_als(opts);
    assert_eq!(ra.fits, rd.fits, "identical fit trajectory");
    assert_eq!(ra.lambda, rd.lambda);
    // one plan per (mode, rank), reused across iterations, on both tiers
    assert_eq!(ra.schedule.built, rd.schedule.built);
    assert_eq!(ra.schedule.hits, rd.schedule.hits);
    assert!(rd.schedule.hits > 0);
    let stats = disk.host_cache_stats().unwrap();
    assert!(stats.peak_resident_bytes <= TIGHT_BUDGET);
    std::fs::remove_file(&path).ok();
}

#[test]
fn registry_admits_disk_tensor_and_bounds_residency() {
    let (path, b, _reader) = build_container("registry", TIGHT_BUDGET);
    // host budget smaller than the payload: the tensor does NOT fit in
    // "host memory", yet the registry serves it
    let payload = b.footprint_bytes();
    let prof = Profile::tiny(1 << 15).with_host_memory(TIGHT_BUDGET);
    assert!(payload > prof.host_mem_bytes, "working set must exceed host RAM");
    let mut reg = TensorRegistry::new(prof);
    reg.register_store("disk", &path).unwrap();
    reg.register("ram", &b.to_coo(), BlcoConfig::default());

    // disk-tier accounting: the container's full footprint is on disk,
    // only (bounded) cache bytes are resident
    assert_eq!(reg.disk_bytes(), payload);
    let entry = &reg.get("disk").unwrap().engine;
    let dims = entry.dims.clone();
    let factors = random_factors(&dims, 8, 11);
    for target in 0..dims.len() {
        let (m, _) = entry.mttkrp(target, &factors);
        let expect = mttkrp_oracle(&b.to_coo(), target, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9, "mode {target}");
    }
    let stats = entry.host_cache_stats().unwrap();
    assert!(stats.peak_resident_bytes <= TIGHT_BUDGET);
    assert!(reg.resident_bytes() < payload + reg.get("ram").unwrap().engine.eng.footprint_bytes());

    // a bad path is a structured error, not a panic
    let err = reg
        .register_store("nope", &tmpfile("missing.blco"))
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(err, StoreError::Io { .. }), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn negative_cases_return_structured_errors() {
    let (path, _b, reader) = build_container("negative", TIGHT_BUDGET);
    drop(reader);
    let good = std::fs::read(&path).unwrap();

    // corrupted magic
    let mut bad = good.clone();
    bad[3] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(
        BlcoStoreReader::open(&path),
        Err(StoreError::BadMagic { .. })
    ));

    // wrong version (2 is the current writer version, so patch in one
    // from the future)
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&path, &bad).unwrap();
    match BlcoStoreReader::open(&path) {
        Err(StoreError::UnsupportedVersion { found: 99, .. }) => {}
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }

    // truncated payload
    std::fs::write(&path, &good[..good.len() - 100]).unwrap();
    assert!(matches!(
        BlcoStoreReader::open(&path),
        Err(StoreError::Truncated { .. })
    ));

    // errors render as readable text through anyhow at the CLI boundary
    let err = BlcoStoreReader::open(&path).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");

    // hostile header: a block nnz far beyond the payload region, with the
    // header checksum recomputed so only the semantic validation can
    // catch it — open must return Malformed, never wrap/abort/panic
    let mut bad = good.clone();
    let header_len =
        u64::from_le_bytes(bad[12..20].try_into().unwrap()) as usize;
    // v2 header blob layout: order u32, dims 3×u64, nnz u64, norm f64,
    // max_block_nnz u64, workgroup u32, inblock_budget u32, default codec
    // u32, nblocks u64, then per-block
    // {key u64, nnz u64, codec u8, stored_len u64, crc u32}
    let first_block_nnz_off = 20 + 4 + 24 + 8 + 8 + 8 + 4 + 4 + 4 + 8 + 8;
    bad[first_block_nnz_off..first_block_nnz_off + 8]
        .copy_from_slice(&(1u64 << 60).to_le_bytes());
    let crc = blco::format::store::crc32(&bad[20..20 + header_len]);
    bad[20 + header_len..24 + header_len].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(&path, &bad).unwrap();
    match BlcoStoreReader::open(&path) {
        Err(StoreError::Malformed { what }) => {
            assert!(what.contains("non-zeros"), "{what}");
        }
        other => panic!("expected Malformed, got {other:?}"),
    }

    std::fs::write(&path, &good).unwrap();
    assert!(BlcoStoreReader::open(&path).is_ok(), "pristine file still opens");
    std::fs::remove_file(&path).ok();
}
