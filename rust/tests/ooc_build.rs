//! Differential suite for the external-memory BLCO build (`tensor::ooc`):
//! the streamed pipeline (chunked parse/generate → sorted runs → k-way
//! merge → `BlcoStoreWriter`) must produce a container **byte-for-byte
//! identical** to `BlcoTensor::from_coo` + `BlcoStore::write` — same
//! blocks, same norm bits, same header CRCs — across seeds, chunk sizes
//! and thread counts, with duplicates preserved exactly and peak
//! accounted memory under an explicit budget while building a tensor
//! several times larger than that budget.

use std::path::PathBuf;

use blco::coordinator::engine::MttkrpEngine;
use blco::cpals::CpAlsOptions;
use blco::device::Profile;
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::BlcoStore;
use blco::tensor::coo::CooTensor;
use blco::tensor::ooc::{build_from_tns, build_uniform, BuildOptions};
use blco::tensor::{io, synth};
use blco::util::pool::ExecBackend;

fn tmpfile(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("blco_oocb_{}_{}", std::process::id(), name));
    p
}

fn small_cfg() -> BlcoConfig {
    BlcoConfig {
        max_block_nnz: 512,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    }
}

/// The in-memory reference: build resident, persist, return the bytes.
fn reference_bytes(t: &CooTensor, cfg: BlcoConfig, name: &str) -> Vec<u8> {
    let p = tmpfile(name);
    BlcoStore::write(&BlcoTensor::from_coo_with(t, cfg), &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::remove_file(&p).ok();
    bytes
}

#[test]
fn streamed_build_is_bitwise_identical_across_seeds_chunks_threads() {
    let dims = [60u64, 50, 40];
    let nnz = 20_000;
    let cfg = small_cfg();
    for seed in [1u64, 99] {
        let expect =
            reference_bytes(&synth::uniform(&dims, nnz, seed), cfg, "sweep_mem.blco");
        for chunk_nnz in [257usize, 4096] {
            for threads in [1usize, 2, 4] {
                let out = tmpfile("sweep_ooc.blco");
                let opts = BuildOptions {
                    config: cfg,
                    backend: ExecBackend::from_threads(threads),
                    chunk_nnz: Some(chunk_nnz),
                    ..Default::default()
                };
                let (summary, stats) =
                    build_uniform(&dims, nnz, seed, &out, &opts).unwrap();
                assert_eq!(stats.entries, summary.nnz as u64);
                assert_eq!(
                    std::fs::read(&out).unwrap(),
                    expect,
                    "seed {seed} chunk {chunk_nnz} threads {threads}"
                );
                std::fs::remove_file(&out).ok();
            }
        }
    }
}

/// Wide shape: total ALTO bits 23+21+22 = 66 > 64, so the u128 line path
/// and the adaptive-blocking key split are both live.
#[test]
fn streamed_build_handles_wide_dims() {
    let dims = [1u64 << 23, 1 << 21, 1 << 22];
    let nnz = 30_000;
    let cfg = small_cfg();
    let expect = reference_bytes(&synth::uniform(&dims, nnz, 5), cfg, "wide_mem.blco");
    let out = tmpfile("wide_ooc.blco");
    let opts = BuildOptions {
        config: cfg,
        backend: ExecBackend::from_threads(2),
        chunk_nnz: Some(3_000),
        ..Default::default()
    };
    let (summary, stats) = build_uniform(&dims, nnz, 5, &out, &opts).unwrap();
    assert!(summary.blocks > 1, "wide shape should split into key blocks");
    assert!(stats.runs >= 10, "runs {}", stats.runs);
    assert_eq!(std::fs::read(&out).unwrap(), expect);
    std::fs::remove_file(&out).ok();
}

#[test]
fn tns_route_inferred_and_explicit_dims_match_in_memory() {
    let t = synth::uniform(&[40, 30, 20], 5_000, 9);
    let tns = tmpfile("route.tns");
    io::write_tns(&tns, &t).unwrap();
    let cfg = small_cfg();

    // in-memory references through the same file (read_tns infers dims the
    // same way the streaming pre-pass does)
    let inferred_ref =
        reference_bytes(&io::read_tns(&tns, None).unwrap(), cfg, "route_mem_i.blco");
    let explicit_ref = reference_bytes(
        &io::read_tns(&tns, Some(&t.dims)).unwrap(),
        cfg,
        "route_mem_e.blco",
    );

    let opts = BuildOptions {
        config: cfg,
        backend: ExecBackend::from_threads(2),
        chunk_nnz: Some(700),
        ..Default::default()
    };
    let out = tmpfile("route_ooc_i.blco");
    let (_, stats) = build_from_tns(&tns, None, &out, &opts).unwrap();
    assert!(stats.infer_s >= 0.0 && stats.runs > 1);
    assert_eq!(std::fs::read(&out).unwrap(), inferred_ref, "inferred dims");
    std::fs::remove_file(&out).ok();

    let out = tmpfile("route_ooc_e.blco");
    build_from_tns(&tns, Some(&t.dims), &out, &opts).unwrap();
    assert_eq!(std::fs::read(&out).unwrap(), explicit_ref, "explicit dims");
    std::fs::remove_file(&out).ok();
    std::fs::remove_file(&tns).ok();
}

/// `from_coo` keeps duplicate coordinates as separate adjacent entries
/// (source order); the merge's global-index tie-break must reproduce that
/// exactly, including when the duplicates land in different chunks.
#[test]
fn duplicate_coordinates_round_trip_identically() {
    let dims = [16u64, 16, 16];
    let mut t = CooTensor::new(&dims);
    let mut rng = 0x9E3779B97F4A7C15u64;
    let mut next = |m: u64| {
        rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (rng >> 33) % m
    };
    for e in 0..4_000u64 {
        let c = [next(16) as u32, next(16) as u32, next(16) as u32];
        t.push(&c, e as f64 * 0.25 - 300.0);
        if e % 5 == 0 {
            // immediate duplicate with a different value: the pair must
            // stay adjacent in source order through the merge
            t.push(&c, -(e as f64));
        }
    }
    let tns = tmpfile("dups.tns");
    io::write_tns(&tns, &t).unwrap();
    let cfg = small_cfg();
    let expect =
        reference_bytes(&io::read_tns(&tns, Some(&dims)).unwrap(), cfg, "dups_mem.blco");
    let out = tmpfile("dups_ooc.blco");
    let opts = BuildOptions {
        config: cfg,
        backend: ExecBackend::from_threads(2),
        chunk_nnz: Some(321), // duplicates split across chunk boundaries
        ..Default::default()
    };
    let (summary, _) = build_from_tns(&tns, Some(&dims), &out, &opts).unwrap();
    assert_eq!(summary.nnz, t.nnz(), "duplicates must not be merged");
    assert_eq!(std::fs::read(&out).unwrap(), expect);
    std::fs::remove_file(&out).ok();
    std::fs::remove_file(&tns).ok();
}

/// The headline acceptance test: a tensor whose raw working set is ~4.6×
/// the budget builds with *accounted peak under the budget*, spills real
/// runs, and still matches the in-memory container bit for bit.
#[test]
fn budget_bounded_build_stays_under_budget() {
    let dims = [4000u64, 3000, 2000]; // sparse: no generator dedup set
    let nnz = 60_000;
    let budget = 256usize << 10;
    let cfg = BlcoConfig {
        max_block_nnz: 2048,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    };
    let expect = reference_bytes(&synth::uniform(&dims, nnz, 7), cfg, "budget_mem.blco");
    assert!(
        expect.len() > 3 * budget,
        "container {} B should dwarf the {} B budget",
        expect.len(),
        budget
    );
    let out = tmpfile("budget_ooc.blco");
    let opts = BuildOptions {
        config: cfg,
        backend: ExecBackend::from_threads(2),
        mem_budget_bytes: Some(budget),
        ..Default::default() // chunk_nnz derived from the budget
    };
    let (_, stats) = build_uniform(&dims, nnz, 7, &out, &opts).unwrap();
    assert!(stats.runs > 4, "expected many spilled runs, got {}", stats.runs);
    assert!(
        stats.peak_bytes <= budget,
        "peak {} B over the {} B budget (runs {}, window {} B)",
        stats.peak_bytes,
        budget,
        stats.runs,
        stats.run_buf_bytes
    );
    assert_eq!(stats.source_bytes, 0, "sparse shape must not dedupe");
    assert_eq!(std::fs::read(&out).unwrap(), expect);
    std::fs::remove_file(&out).ok();
}

/// End-to-end: a CP-ALS decomposition running host-out-of-core from the
/// *streamed* artifact follows the exact fit trajectory of the resident
/// engine built from the same COO data (single thread → one float order).
#[test]
fn cpals_fit_trajectory_matches_from_streamed_artifact() {
    let dims = [50u64, 40, 30];
    let nnz = 8_000;
    let cfg = small_cfg();
    let out = tmpfile("cpals_ooc.blco");
    let opts = BuildOptions {
        config: cfg,
        backend: ExecBackend::from_threads(2),
        chunk_nnz: Some(1_000),
        ..Default::default()
    };
    build_uniform(&dims, nnz, 13, &out, &opts).unwrap();

    let als = CpAlsOptions {
        rank: 8,
        max_iters: 6,
        tol: 0.0, // run all iterations: compare full trajectories
        threads: 1,
        seed: 0xCA1,
    };
    let profile = Profile::by_name("a100").unwrap();
    let streamed = MttkrpEngine::from_store(&out, profile.clone())
        .unwrap()
        .with_threads(1)
        .cp_als(als);
    let resident = MttkrpEngine::from_coo_with(&synth::uniform(&dims, nnz, 13), profile, cfg)
        .with_threads(1)
        .cp_als(als);
    assert_eq!(streamed.iterations, resident.iterations);
    assert_eq!(streamed.fits, resident.fits, "fit trajectories diverged");
    assert!(
        streamed.fits.iter().all(|f| f.is_finite()),
        "non-finite fit in {:?}",
        streamed.fits
    );
    std::fs::remove_file(&out).ok();
}
