//! Integration: every parallel engine × every small preset × every mode
//! agrees with the serial COO oracle — the repository's strongest
//! correctness statement (all formats encode the same tensor; all conflict
//! resolution schemes converge to the same MTTKRP).

use blco::device::{Counters, Profile};
use blco::format::blco::BlcoTensor;
use blco::format::fcoo::FCoo;
use blco::mttkrp::blco::{BlcoEngine, Resolution};
use blco::mttkrp::coo::CooAtomicEngine;
use blco::mttkrp::csf::{BCsfEngine, CsfEngine, MmCsfEngine};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::fcoo::FCooEngine;
use blco::mttkrp::genten::GenTenEngine;
use blco::mttkrp::hicoo::HicooEngine;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::mttkrp::Mttkrp;
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;

fn engines(t: &CooTensor) -> Vec<Box<dyn Mttkrp>> {
    vec![
        Box::new(CooAtomicEngine::new(t.clone())),
        Box::new(GenTenEngine::new(t.clone())),
        Box::new(HicooEngine::new(
            blco::format::hicoo::HicooTensor::from_coo(t, 6),
        )),
        Box::new(FCooEngine::new(FCoo::from_coo(t, 128))),
        Box::new(CsfEngine::new(t)),
        Box::new(BCsfEngine::new(t, 256)),
        Box::new(MmCsfEngine::new(t)),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo(t), Profile::a100())
                .with_resolution(Resolution::Register),
        ),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo(t), Profile::a100())
                .with_resolution(Resolution::Hierarchical),
        ),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo(t), Profile::intel_d1())
                .with_resolution(Resolution::Auto),
        ),
    ]
}

fn cross_check(t: &CooTensor, rank: usize) {
    let factors = random_factors(&t.dims, rank, 0xC0FFEE);
    for target in 0..t.order() {
        let expect = mttkrp_oracle(t, target, &factors);
        for eng in engines(t) {
            let mut out = Matrix::zeros(t.dims[target] as usize, rank);
            eng.mttkrp(target, &factors, &mut out, 8, &Counters::new());
            let d = out.max_abs_diff(&expect);
            assert!(
                d < 1e-8,
                "{} mode {target}: max diff {d:e} (dims {:?})",
                eng.name(),
                t.dims
            );
        }
    }
}

#[test]
fn uniform_3mode() {
    cross_check(&synth::uniform(&[70, 50, 30], 6_000, 1), 16);
}

#[test]
fn uniform_4mode() {
    cross_check(&synth::uniform(&[24, 20, 16, 12], 4_000, 2), 8);
}

#[test]
fn fiber_clustered_skewed() {
    cross_check(&synth::fiber_clustered(&[60, 80, 100], 8_000, 2, 1.2, 3), 16);
}

#[test]
fn short_mode_contention() {
    // dims[0]=4 stresses the atomic paths and the hierarchical heuristic
    cross_check(&synth::uniform(&[4, 200, 200], 10_000, 5), 32);
}

#[test]
fn hypersparse_low_fiber_density() {
    // DARPA-like: nnz ≈ distinct fibers (MM-CSF's worst case)
    cross_check(&synth::uniform(&[500, 500, 2000], 3_000, 7), 8);
}

#[test]
fn single_nonzero_and_tiny() {
    let mut t = CooTensor::new(&[3, 3, 3]);
    t.push(&[1, 2, 0], 2.5);
    cross_check(&t, 4);
}

#[test]
fn rank_one() {
    cross_check(&synth::uniform(&[30, 30, 30], 1_000, 11), 1);
}

#[test]
fn max_rank_boundary() {
    cross_check(&synth::uniform(&[20, 20, 20], 800, 13), 64);
}
