//! The parallel-execution invariant: a certified engine produces the
//! SAME BITS at every thread count — resident or disk-backed, for every
//! mode and every conflict resolution. The certificate's wave schedule
//! replays each row's flushes in submission order and the hierarchical
//! path assigns each shadow copy to exactly one worker, so threading
//! never reassociates a float add. CP-ALS inherits the invariant
//! end-to-end: whole fit trajectories are bit-identical across thread
//! counts.

use std::path::PathBuf;
use std::sync::Arc;

use blco::coordinator::engine::MttkrpEngine;
use blco::cpals::CpAlsOptions;
use blco::device::Profile;
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::BlcoStore;
use blco::mttkrp::blco::Resolution;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::synth;

const RANK: usize = 8;
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn tmpfile(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("blco_pexec_{}_{}", std::process::id(), name));
    p
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// A tensor whose BLCO form has a real multi-batch, multi-group schedule
/// (small blocks, small work-groups), persisted so the disk axis streams
/// through the block cache.
fn build(name: &str) -> (Arc<BlcoTensor>, PathBuf) {
    let t = synth::fiber_clustered(&[60, 50, 40], 8_000, 2, 0.8, 3);
    let cfg = BlcoConfig {
        max_block_nnz: 512,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    };
    let b = BlcoTensor::from_coo_with(&t, cfg);
    assert!(b.batches.len() > 4, "need a real batch pipeline");
    let path = tmpfile(&format!("{name}.blco"));
    BlcoStore::write(&b, &path).unwrap();
    (Arc::new(b), path)
}

#[test]
fn certified_kernels_are_bitwise_across_thread_counts_resident_and_store() {
    let (b, path) = build("matrix");
    let dims = b.dims().to_vec();
    let factors = random_factors(&dims, RANK, 5);
    let profile = Profile::a100();

    for res in [Resolution::Register, Resolution::Hierarchical, Resolution::Auto]
    {
        // the sequential certified run is the reference everyone must hit
        let seq = MttkrpEngine::from_blco(Arc::clone(&b), profile.clone())
            .with_resolution(res)
            .with_conflict_analysis()
            .with_threads(1);
        for target in 0..dims.len() {
            let (want, _) = seq.mttkrp(target, &factors);
            let want = bits(&want);
            for nt in THREADS {
                let resident =
                    MttkrpEngine::from_blco(Arc::clone(&b), profile.clone())
                        .with_resolution(res)
                        .with_conflict_analysis()
                        .with_threads(nt);
                let (got, _) = resident.mttkrp(target, &factors);
                assert_eq!(
                    bits(&got),
                    want,
                    "resident {res:?} mode {target} at {nt} threads"
                );

                let disk = MttkrpEngine::from_store(&path, profile.clone())
                    .unwrap()
                    .with_resolution(res)
                    .with_conflict_analysis()
                    .with_threads(nt);
                let (got, _) = disk.mttkrp(target, &factors);
                assert_eq!(
                    bits(&got),
                    want,
                    "from-store {res:?} mode {target} at {nt} threads"
                );
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn cpals_fit_trajectory_is_bitwise_across_thread_counts() {
    let (b, path) = build("cpals");
    let profile = Profile::a100();
    let run = |nt: usize| {
        let engine = MttkrpEngine::from_blco(Arc::clone(&b), profile.clone())
            .with_conflict_analysis()
            .with_threads(nt);
        let opts =
            CpAlsOptions { rank: 6, max_iters: 4, tol: 0.0, threads: nt, seed: 7 };
        engine.cp_als(opts)
    };
    let want = run(1);
    let want_fits: Vec<u64> = want.fits.iter().map(|f| f.to_bits()).collect();
    assert!(!want_fits.is_empty(), "tol = 0 must run every iteration");
    for nt in [2usize, 4, 8] {
        let got = run(nt);
        let got_fits: Vec<u64> = got.fits.iter().map(|f| f.to_bits()).collect();
        assert_eq!(
            got_fits, want_fits,
            "CP-ALS fit trajectory diverged at {nt} threads"
        );
    }
    std::fs::remove_file(&path).ok();
}
