//! End-to-end coverage of the streaming schedule subsystem and mode-aware
//! OOM routing: the same seeded tensor decomposed through in-memory,
//! streamed and clustered engines must produce matching fit trajectories;
//! the schedule cache must plan once per distinct `(mode, rank)` pair (not
//! `modes × iterations`); and a mixed tensor must route short modes
//! in-memory while its long mode streams — all over one tensor copy.

use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::coordinator::schedule::{Placement, ScheduleStats, StreamSchedule};
use blco::cpals::CpAlsOptions;
use blco::device::Profile;
use blco::format::blco::BlcoConfig;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::tensor::synth;

fn opts(rank: usize, iters: usize) -> CpAlsOptions {
    CpAlsOptions { rank, max_iters: iters, tol: 0.0, threads: 4, seed: 9 }
}

#[test]
fn oom_cpals_matches_in_memory_fit_trajectory() {
    // one seeded tensor, three engines: big device (all in-memory), tiny
    // device (every mode streamed), tiny 2-device cluster (every mode
    // sharded). The decomposition must not care which path ran.
    let t = synth::fiber_clustered(&[30, 24, 18], 4_000, 2, 0.9, 11);
    let cfg = BlcoConfig { max_block_nnz: 256, ..Default::default() };
    let o = opts(6, 5);

    let big = MttkrpEngine::from_coo_with(&t, Profile::a100(), cfg).with_threads(4);
    let small =
        MttkrpEngine::from_coo_with(&t, Profile::tiny(16 * 1024), cfg).with_threads(4);
    let cluster = MttkrpEngine::from_coo_with(
        &t,
        Profile::tiny(16 * 1024).with_devices(2),
        cfg,
    )
    .with_threads(4);
    assert!(!big.is_oom(o.rank));
    assert!(small.is_oom_for(0, o.rank) && small.is_oom_for(2, o.rank));

    let r_mem = big.cp_als(o);
    let r_str = small.cp_als(o);
    let r_clu = cluster.cp_als(o);

    assert_eq!(r_mem.fits.len(), 5);
    assert_eq!(r_str.fits.len(), 5);
    assert_eq!(r_clu.fits.len(), 5);
    for i in 0..5 {
        assert!(
            (r_mem.fits[i] - r_str.fits[i]).abs() < 1e-4,
            "iter {i}: in-memory {} vs streamed {}",
            r_mem.fits[i],
            r_str.fits[i]
        );
        assert!(
            (r_mem.fits[i] - r_clu.fits[i]).abs() < 1e-4,
            "iter {i}: in-memory {} vs clustered {}",
            r_mem.fits[i],
            r_clu.fits[i]
        );
    }

    // and each engine took the path its profile dictates, every call
    for tr in &r_mem.mode_traces {
        assert_eq!((tr.in_memory, tr.streamed, tr.clustered), (5, 0, 0));
    }
    for tr in &r_str.mode_traces {
        assert_eq!((tr.in_memory, tr.streamed, tr.clustered), (0, 5, 0));
        assert!(matches!(tr.last, Some(ExecPath::Streamed(_))));
    }
    for tr in &r_clu.mode_traces {
        assert_eq!((tr.in_memory, tr.streamed, tr.clustered), (0, 0, 5));
        assert!(matches!(tr.last, Some(ExecPath::Clustered(_))));
    }
    assert!(r_str.stream.bytes > 0);
    assert!(r_clu.stream.merge_bytes > 0, "cluster runs charge merge traffic");
}

#[test]
fn cpals_plans_once_per_distinct_mode_rank_pair() {
    let t = synth::fiber_clustered(&[40, 30, 20], 5_000, 2, 1.0, 31);
    let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
    let engine =
        MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg).with_threads(4);
    for m in 0..3 {
        assert!(engine.is_oom_for(m, 8), "mode {m} must stream");
    }

    let iters = 6;
    let rep = engine.cp_als(opts(8, iters));
    assert_eq!(rep.iterations, iters);
    assert_eq!(
        rep.schedule,
        ScheduleStats { built: 3, hits: 3 * (iters - 1) },
        "one plan per (mode, rank), every later iteration a cache hit"
    );
    assert_eq!(rep.stream.streamed_calls, 3 * iters);

    // a second decomposition at the same rank reuses the same 3 plans...
    let rep2 = engine.cp_als(opts(8, 2));
    assert_eq!(rep2.schedule, ScheduleStats { built: 0, hits: 6 });
    // ...and a different rank plans 3 fresh ones
    let rep3 = engine.cp_als(opts(4, 2));
    assert_eq!(rep3.schedule, ScheduleStats { built: 3, hits: 3 });
}

#[test]
fn cold_engine_plans_every_iteration() {
    // the pre-cache behavior, kept reachable as the bench baseline: plans
    // built must be modes × iterations and results must not change
    let t = synth::fiber_clustered(&[40, 30, 20], 5_000, 2, 1.0, 31);
    let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
    let cached =
        MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg).with_threads(4);
    let cold = MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg)
        .with_threads(4)
        .with_schedule_caching(false);

    let iters = 4;
    let rc = cached.cp_als(opts(8, iters));
    let rf = cold.cp_als(opts(8, iters));
    assert_eq!(rf.schedule, ScheduleStats { built: 3 * iters, hits: 0 });
    for i in 0..iters {
        assert!(
            (rc.fits[i] - rf.fits[i]).abs() < 1e-5,
            "caching must not change the math (iter {i}): {} vs {}",
            rc.fits[i],
            rf.fits[i]
        );
    }
}

#[test]
fn mixed_tensor_routes_per_mode_through_cpals() {
    // one long mode (streams) + two short modes (fit in-memory): the
    // mode-aware facade mixes paths inside a single ALS sweep on one
    // tensor copy
    let t = synth::uniform(&[4096, 8, 8], 2_000, 3);
    let cfg = BlcoConfig { max_block_nnz: 256, ..Default::default() };
    let engine =
        MttkrpEngine::from_coo_with(&t, Profile::tiny(800 * 1024), cfg).with_threads(4);
    let rank = 16;
    assert!(engine.is_oom(rank), "conservative classification: OOM");
    assert!(engine.is_oom_for(0, rank));
    assert!(!engine.is_oom_for(1, rank) && !engine.is_oom_for(2, rank));

    let iters = 3;
    let rep = engine.cp_als(opts(rank, iters));
    assert_eq!(
        (rep.mode_traces[0].streamed, rep.mode_traces[0].in_memory),
        (iters, 0),
        "long mode streams every iteration"
    );
    for m in 1..3 {
        assert_eq!(
            (rep.mode_traces[m].in_memory, rep.mode_traces[m].streamed),
            (iters, 0),
            "short mode {m} stays in-memory"
        );
    }
    // only the streamed mode needed a plan, built exactly once
    assert_eq!(rep.schedule, ScheduleStats { built: 1, hits: iters - 1 });
}

#[test]
fn prebuilt_schedule_reuse_is_exact_across_iterations() {
    // the schedule consumed by iteration 10 is the *same object* built at
    // iteration 1 (Arc identity), and replanning from scratch produces an
    // identical plan — so reuse can never drift from cold planning
    let t = synth::fiber_clustered(&[40, 30, 20], 5_000, 2, 1.0, 31);
    let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
    let engine = MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg);
    let a = engine.schedule(0, 8);
    let b = engine.schedule(0, 8);
    assert!(std::sync::Arc::ptr_eq(&a, &b));

    let beng = BlcoEngine::new(
        blco::format::blco::BlcoTensor::from_coo_with(&t, cfg),
        Profile::tiny(32 * 1024),
    );
    let fresh = StreamSchedule::build(&beng, 0, 8, Placement::Greedy);
    assert_eq!(a.assign, fresh.assign);
    assert_eq!(a.queue_of, fresh.queue_of);
    assert_eq!(a.link_of, fresh.link_of);
    assert_eq!(a.bytes, fresh.bytes);
    assert_eq!(a.transfer_s, fresh.transfer_s);
}

#[test]
fn direct_mttkrp_calls_agree_with_oracle_on_mixed_routing() {
    let t = synth::uniform(&[4096, 8, 8], 2_000, 3);
    let cfg = BlcoConfig { max_block_nnz: 256, ..Default::default() };
    let engine = MttkrpEngine::from_coo_with(&t, Profile::tiny(800 * 1024), cfg);
    let factors = random_factors(&t.dims, 16, 7);
    for target in 0..3 {
        let (m, _) = engine.mttkrp(target, &factors);
        let expect = mttkrp_oracle(&t, target, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9, "mode {target}");
    }
}
