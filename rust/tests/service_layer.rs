//! Integration suite for the multi-tenant serving layer: a mixed-tenant
//! trace over one shared tensor copy must (i) produce oracle-correct
//! results on every route, (ii) reuse streaming schedules across repeated
//! `(tensor, mode, rank)` jobs, (iii) beat the one-job-at-a-time baseline
//! on modelled makespan via fused streaming, (iv) interleave tenants
//! fairly under weighted round-robin, (v) reject unservable requests
//! with structured errors instead of panicking — and, with the
//! production-serving stack: (vi) track queue depth on every
//! enqueue/dequeue event instead of sampling at dispatch instants,
//! (vii) beat WRR on deadline-miss rate under EDF at equal throughput,
//! (viii) shed overloaded streamed jobs to coarser ranks instead of
//! rejecting them, and (ix) serve snapshot-consistent pre/post-append
//! views of one on-disk container, each bit-for-bit against its resident
//! twin.

use std::sync::Arc;

use blco::device::Profile;
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::BlcoStoreReader;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::mttkrp::MAX_RANK;
use blco::service::{
    AdmissionError, JobKind, JobRequest, JobResult, JobStatus, Route, SchedPolicy,
    ServeRequest, ServiceReport, ShedPolicy, Tenant, TensorRegistry,
};
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;

/// Registry with one in-memory tensor ("hot") and one streamed tensor
/// ("cold") on a 48 KiB device; returns the COO forms for oracle checks.
fn registry() -> (TensorRegistry, CooTensor, CooTensor) {
    let hot = synth::uniform(&[40, 30, 20], 1_000, 1);
    let cold = synth::uniform(&[60, 50, 40], 8_000, 2);
    let mut reg = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg.register("hot", &hot, BlcoConfig::default());
    reg.register(
        "cold",
        &cold,
        BlcoConfig { max_block_nnz: 512, ..Default::default() },
    );
    // the intended routing mix, asserted up front so the fixtures cannot
    // silently drift
    let hot_eng = &reg.get("hot").unwrap().engine;
    let cold_eng = &reg.get("cold").unwrap().engine;
    assert!(!hot_eng.is_oom_for(0, 8), "hot must run in-memory");
    assert!(cold_eng.is_oom_for(0, 8), "cold must stream");
    assert!(cold_eng.can_serve(0, 8), "cold must be streamable");
    (reg, hot, cold)
}

fn mttkrp_job(
    id: usize,
    tenant: &str,
    tensor: &str,
    target: usize,
    rank: usize,
    seed: u64,
    arrival_s: f64,
) -> JobRequest {
    JobRequest::new(id, tenant, tensor, JobKind::Mttkrp { target, rank, seed }, arrival_s)
}

fn tenants(weights: &[usize]) -> Vec<Tenant> {
    weights
        .iter()
        .enumerate()
        .map(|(i, &w)| Tenant { name: format!("t{i}"), weight: w })
        .collect()
}

/// The full policy: WRR fairness + fused streaming.
fn serve_batched(
    reg: &TensorRegistry,
    ten: &[Tenant],
    jobs: &[JobRequest],
    devices: usize,
    threads: usize,
) -> ServiceReport {
    ServeRequest::new(reg)
        .trace(ten, jobs)
        .devices(devices)
        .threads(threads)
        .run()
        .expect("valid request")
        .into_report()
}

/// The one-job-at-a-time ablation baseline: no fusion, global FIFO.
fn serve_naive(
    reg: &TensorRegistry,
    ten: &[Tenant],
    jobs: &[JobRequest],
    devices: usize,
    threads: usize,
) -> ServiceReport {
    ServeRequest::new(reg)
        .trace(ten, jobs)
        .policy(SchedPolicy::Fifo)
        .batching(false)
        .devices(devices)
        .threads(threads)
        .run()
        .expect("valid request")
        .into_report()
}

#[test]
fn mixed_trace_is_oracle_correct_with_cache_hits_and_fusion() {
    let (reg, hot, cold) = registry();
    let ten = tenants(&[1, 1]);
    // burst at t=0: repeated (cold, mode 0, rank 8) keys from both tenants
    // (fusible), plus hot in-memory jobs and a second cold mode
    let jobs = vec![
        mttkrp_job(0, "t0", "cold", 0, 8, 100, 0.0),
        mttkrp_job(1, "t1", "cold", 0, 8, 101, 0.0),
        mttkrp_job(2, "t0", "cold", 0, 8, 102, 0.0),
        mttkrp_job(3, "t1", "hot", 1, 8, 103, 0.0),
        mttkrp_job(4, "t0", "cold", 2, 8, 104, 0.0),
        mttkrp_job(5, "t1", "cold", 0, 8, 105, 0.0),
        mttkrp_job(6, "t0", "hot", 0, 8, 106, 0.0),
        mttkrp_job(7, "t1", "cold", 2, 8, 107, 0.0),
    ];
    let rep = serve_batched(&reg, &ten, &jobs, 1, 4);
    assert_eq!(rep.completed(), 8);
    assert_eq!(rep.rejected(), 0);

    // every result matches the serial oracle for its own factors
    for o in &rep.outcomes {
        let (target, rank, seed) = match o.kind {
            JobKind::Mttkrp { target, rank, seed } => (target, rank, seed),
            _ => unreachable!(),
        };
        let src = if o.tensor == "hot" { &hot } else { &cold };
        let factors = random_factors(&src.dims, rank, seed);
        let expect = mttkrp_oracle(src, target, &factors);
        match o.result.as_ref().expect("completed jobs carry results") {
            JobResult::Mttkrp(m) => {
                let d = m.max_abs_diff(&expect);
                assert!(d < 1e-9, "job {} diverges by {d:e}", o.id);
            }
            JobResult::CpAls(_) => unreachable!(),
        }
        assert!(o.finish_s >= o.start_s);
        assert!(o.latency_s >= 0.0);
        assert_eq!(o.served_rank, Some(rank), "no shed policy: requested rank");
        assert!(!o.shed);
    }

    // the t=0 burst of same-key streamed jobs fuses — but never past the
    // device-memory capacity: on this 48 KiB fixture k resident
    // factor/output sets cap each group at 2 jobs
    let grouped: Vec<&_> = rep
        .outcomes
        .iter()
        .filter(|o| o.group.is_some())
        .collect();
    assert!(rep.fused_groups >= 2, "burst of same-key jobs must fuse");
    assert_eq!(rep.fused_jobs, grouped.len());
    assert!(grouped.len() >= 4, "the streamed repeats ride fused passes");
    for o in &grouped {
        assert_eq!(o.route, Some(Route::Streamed));
    }
    // fusion respects the admission-guaranteed memory budget
    let cold_eng = &reg.get("cold").unwrap().engine;
    for gid in 0..rep.fused_groups {
        let size = rep.outcomes.iter().filter(|o| o.group == Some(gid)).count();
        assert!(
            size <= cold_eng.fused_jobs_capacity(0, 8).max(cold_eng.fused_jobs_capacity(2, 8)),
            "group {gid} of {size} jobs overcommits device memory"
        );
    }

    // distinct streamed keys: (cold,0,8) and (cold,2,8) → 2 plans built;
    // the capacity cap splits the mode-0 burst into two dispatches, and
    // the second one must hit the cache
    assert_eq!(rep.schedule.built, 2, "one plan per distinct (tensor, mode, rank)");
    assert!(rep.schedule.hits >= 1, "repeated key reuses the memoized plan");
    // queue depth under event accounting: the whole t=0 burst (4 jobs per
    // tenant) is enqueued before the first dispatch
    for s in rep.per_tenant.values() {
        assert_eq!(s.max_queue_depth, 4, "t=0 burst backlog");
    }
    assert!((rep.queue_depth.max - 8.0).abs() < 1e-12, "aggregate backlog peaks at 8");
    assert!(rep.makespan_s > 0.0);
    assert!(rep.bytes_shipped > 0);
    // aggregate latency tails are populated and ordered
    assert!(rep.latency.p50 > 0.0);
    assert!(rep.latency.p50 <= rep.latency.p99 + 1e-18);
    assert!(rep.latency.p99 <= rep.latency.max + 1e-18);
}

#[test]
fn repeated_keys_hit_the_schedule_cache() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1]);
    // spaced far apart so nothing fuses: every repeat must hit the cache
    let jobs: Vec<JobRequest> = (0..5)
        .map(|i| mttkrp_job(i, "t0", "cold", 1, 8, 200 + i as u64, i as f64 * 10.0))
        .collect();
    let rep = serve_batched(&reg, &ten, &jobs, 1, 4);
    assert_eq!(rep.completed(), 5);
    assert_eq!(rep.fused_groups, 0, "spaced jobs must not fuse");
    assert_eq!(rep.schedule.built, 1);
    assert_eq!(rep.schedule.hits, 4, "every repeat reuses the plan");
    assert!(rep.cache_hit_rate() > 0.0);
}

#[test]
fn queue_depth_tracks_events_not_dispatch_samples() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1]);
    // four spread-out in-memory jobs: each finishes (modelled) long before
    // the next arrives, so the queue never holds more than one job. The
    // old accounting seeded each tenant's max with its *whole future
    // trace* (4 here, counting jobs that had not arrived) and then only
    // sampled at dispatch instants — this trace pins the difference.
    let jobs: Vec<JobRequest> = (0..4)
        .map(|i| mttkrp_job(i, "t0", "hot", 0, 8, 600 + i as u64, i as f64 * 10.0))
        .collect();
    let rep = serve_batched(&reg, &ten, &jobs, 1, 2);
    assert_eq!(rep.completed(), 4);
    let s = rep.per_tenant.get("t0").unwrap();
    assert_eq!(
        s.max_queue_depth, 1,
        "event accounting: a spread trace never stacks (the old \
         dispatch-instant sampling reported {})",
        jobs.len()
    );
    // every enqueue and dequeue leaves a sample: [1,0,1,0,1,0,1,0]
    assert!((rep.queue_depth.max - 1.0).abs() < 1e-12);
    assert!((rep.queue_depth.p50 - 0.5).abs() < 1e-12, "half the events see an empty queue");
    assert!((s.queue_depth.max - 1.0).abs() < 1e-12);

    // contrast: the same four jobs as a t=0 burst DO stack to depth 4
    let burst: Vec<JobRequest> = (0..4)
        .map(|i| mttkrp_job(i, "t0", "hot", 0, 8, 600 + i as u64, 0.0))
        .collect();
    let rep = serve_batched(&reg, &ten, &burst, 1, 2);
    assert_eq!(rep.per_tenant.get("t0").unwrap().max_queue_depth, 4);
}

#[test]
fn batched_beats_one_job_at_a_time_on_makespan() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1, 1]);
    // a backlog of same-key streamed jobs: fusion ships the tensor once
    // per group instead of once per job
    let jobs: Vec<JobRequest> = (0..6)
        .map(|i| {
            mttkrp_job(i, if i % 2 == 0 { "t0" } else { "t1" }, "cold", 0, 8, 300 + i as u64, 0.0)
        })
        .collect();
    let batched = serve_batched(&reg, &ten, &jobs, 1, 4);

    // fresh registry sharing the same payload Arc for the cold baseline
    let mut reg2 = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg2.register_shared("cold", reg.get("cold").unwrap().engine.tensor());
    let naive = serve_naive(&reg2, &ten, &jobs, 1, 4);

    assert_eq!(batched.completed(), 6);
    assert_eq!(naive.completed(), 6);
    assert!(batched.fused_groups >= 1);
    assert_eq!(naive.fused_groups, 0);
    assert!(
        batched.makespan_s < naive.makespan_s,
        "fused streaming must win: {} vs {}",
        batched.makespan_s,
        naive.makespan_s
    );
    assert!(
        batched.bytes_shipped < naive.bytes_shipped,
        "fusion ships the payload fewer times"
    );
    // fleet parallelism compounds: two devices can't be slower
    let mut reg4 = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg4.register_shared("cold", reg.get("cold").unwrap().engine.tensor());
    let two_dev = serve_naive(&reg4, &ten, &jobs, 2, 4);
    assert!(two_dev.makespan_s <= naive.makespan_s + 1e-12);
}

#[test]
fn weighted_round_robin_interleaves_and_protects_latecomers() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1, 1]);
    // t0 submits 8 jobs first (lower ids), t1 8 jobs after — all at t=0,
    // all in-memory (hot) so nothing fuses and dispatch order is visible
    let mut jobs = Vec::new();
    for i in 0..8 {
        jobs.push(mttkrp_job(i, "t0", "hot", i % 3, 8, 400 + i as u64, 0.0));
    }
    for i in 0..8 {
        jobs.push(mttkrp_job(8 + i, "t1", "hot", i % 3, 8, 500 + i as u64, 0.0));
    }
    let fair = serve_batched(&reg, &ten, &jobs, 1, 4);
    let fifo = serve_naive(&reg, &ten, &jobs, 1, 4);

    // dispatch order: sort completed outcomes by start instant
    let order = |rep: &ServiceReport| -> Vec<String> {
        let mut done: Vec<(f64, usize, String)> = rep
            .outcomes
            .iter()
            .map(|o| (o.start_s, o.id, o.tenant.clone()))
            .collect();
        done.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        done.into_iter().map(|(_, _, t)| t).collect()
    };
    let fair_order = order(&fair);
    let fifo_order = order(&fifo);
    // FIFO starves the latecomer: every t0 job dispatches first
    assert!(fifo_order[..8].iter().all(|t| t == "t0"), "{fifo_order:?}");
    // WRR interleaves: both tenants appear within the first 3 dispatches
    assert!(
        fair_order[..3].iter().any(|t| t == "t0")
            && fair_order[..3].iter().any(|t| t == "t1"),
        "{fair_order:?}"
    );
    // and the latecomer's mean latency improves under fairness
    let t1_fair = fair.per_tenant.get("t1").unwrap().mean_latency_s;
    let t1_fifo = fifo.per_tenant.get("t1").unwrap().mean_latency_s;
    assert!(t1_fair < t1_fifo, "fair {t1_fair} vs fifo {t1_fifo}");

    // weighted: a weight-2 tenant gets ~2/3 of early dispatches
    let weighted = tenants(&[2, 1]);
    let wrep = serve_batched(&reg, &weighted, &jobs, 1, 4);
    let worder = order(&wrep);
    let t0_early = worder[..9].iter().filter(|t| *t == "t0").count();
    assert!(t0_early >= 5, "weight-2 tenant got {t0_early}/9: {worder:?}");
}

#[test]
fn edf_beats_wrr_on_deadline_miss_rate_at_equal_throughput() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1]);
    // probe the modelled service time of one streamed (cold, 0, 8) job so
    // the scenario's deadlines are profile-independent
    let probe_jobs = vec![mttkrp_job(0, "t0", "cold", 0, 8, 700, 0.0)];
    let probe = serve_batched(&reg, &ten, &probe_jobs, 1, 2);
    let d = probe.outcomes[0].duration_s;
    assert!(d > 0.0 && d.is_finite());

    // the pinned scenario (ROADMAP item 4): six identical jobs at t=0 on
    // one tenant and one device — ids 0-2 loose (100·d), ids 3-5 tight
    // (3.5·d). WRR within one tenant is FIFO, so the tight jobs wait for
    // the loose ones and finish at 4d/5d/6d — all three miss. EDF serves
    // the tight tier first (finish d/2d/3d ≤ 3.5d) and misses none. Both
    // policies complete the same jobs in the same total time: the win is
    // pure ordering, not throughput.
    let jobs: Vec<JobRequest> = (0..6)
        .map(|i| {
            mttkrp_job(i, "t0", "cold", 0, 8, 710 + i as u64, 0.0)
                .with_deadline(if i < 3 { 100.0 * d } else { 3.5 * d })
        })
        .collect();
    let run = |policy: SchedPolicy| {
        ServeRequest::new(&reg)
            .trace(&ten, &jobs)
            .policy(policy)
            .devices(1)
            .threads(2)
            .batching(false)
            .run()
            .expect("valid request")
            .into_report()
    };
    let wrr = run(SchedPolicy::Wrr);
    let edf = run(SchedPolicy::Edf);

    assert_eq!(wrr.completed(), 6);
    assert_eq!(edf.completed(), 6);
    assert_eq!(
        wrr.makespan_s.to_bits(),
        edf.makespan_s.to_bits(),
        "identical service demand: equal throughput"
    );
    assert_eq!(wrr.deadline_jobs, 6);
    assert_eq!(wrr.deadline_misses, 3, "FIFO order blows every tight deadline");
    assert_eq!(edf.deadline_misses, 0, "EDF serves the tight tier first");
    assert!(edf.deadline_miss_rate() < wrr.deadline_miss_rate());

    // outcome-level deadline accounting is consistent with the aggregate
    let misses = |rep: &ServiceReport| {
        rep.outcomes.iter().filter(|o| o.missed_deadline).count()
    };
    assert_eq!(misses(&wrr), 3);
    assert_eq!(misses(&edf), 0);

    // priority tiers dominate deadlines: demoting the tight jobs to a
    // lower-priority tier under EDF restores the FIFO-like miss pattern
    let demoted: Vec<JobRequest> = jobs
        .iter()
        .cloned()
        .map(|j| if j.id >= 3 { j.with_priority(1) } else { j })
        .collect();
    let edf_demoted = ServeRequest::new(&reg)
        .trace(&ten, &demoted)
        .policy(SchedPolicy::Edf)
        .devices(1)
        .threads(2)
        .batching(false)
        .run()
        .expect("valid request")
        .into_report();
    assert_eq!(edf_demoted.deadline_misses, 3, "tier outranks deadline");
}

#[test]
fn overloaded_streamed_jobs_shed_to_coarser_ranks_and_complete() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1]);
    let probe_jobs = vec![mttkrp_job(0, "t0", "cold", 0, 8, 800, 0.0)];
    let d = serve_batched(&reg, &ten, &probe_jobs, 1, 2).outcomes[0].duration_s;

    // a t=0 backlog with a 2·d deadline: by the time the later jobs reach
    // the head of the queue they have burned over half their budget, so
    // dispatch-level shedding halves their rank instead of missing wide
    let jobs: Vec<JobRequest> = (0..5)
        .map(|i| {
            mttkrp_job(i, "t0", "cold", 0, 8, 810 + i as u64, 0.0)
                .with_deadline(2.0 * d)
        })
        .collect();
    let rep = ServeRequest::new(&reg)
        .trace(&ten, &jobs)
        .devices(1)
        .threads(2)
        .batching(false)
        .shed(ShedPolicy { wait_frac: 0.5, min_rank: 2 })
        .run()
        .expect("valid request")
        .into_report();
    assert_eq!(rep.completed(), 5, "shedding degrades, it does not reject");
    assert_eq!(rep.rejected(), 0);
    assert!(rep.shed_jobs >= 1, "the backlog tail must shed");
    for o in &rep.outcomes {
        assert!(matches!(o.status, JobStatus::Completed));
        if o.shed {
            assert_eq!(o.served_rank, Some(4), "rank 8 halves to 4");
        } else {
            assert_eq!(o.served_rank, Some(8));
        }
    }
    // shed jobs still return usable (coarser) results
    let shed_out = rep.outcomes.iter().find(|o| o.shed).unwrap();
    match shed_out.result.as_ref().unwrap() {
        JobResult::Mttkrp(m) => assert_eq!(m.cols, 4),
        JobResult::CpAls(_) => unreachable!(),
    }

    // admission-level shedding: a budget between the rank-8 and rank-2
    // streaming floors turns WontFit into a degraded admission
    let cold_eng = &reg.get("cold").unwrap().engine;
    let f8 = cold_eng.streaming_floor_bytes(0, 8);
    let f2 = cold_eng.streaming_floor_bytes(0, 2);
    assert!(f2 < f8);
    let mut starved = TensorRegistry::new(Profile::tiny((f8 + f2) / 2));
    starved.register_shared("cold", cold_eng.tensor());
    let job = vec![mttkrp_job(0, "t0", "cold", 0, 8, 820, 0.0)];
    // without shedding: structured rejection
    let rep = serve_batched(&starved, &ten, &job, 1, 2);
    assert_eq!(rep.rejected(), 1);
    // with shedding: admitted at a halved rank and completed
    let rep = ServeRequest::new(&starved)
        .trace(&ten, &job)
        .devices(1)
        .threads(2)
        .shed(ShedPolicy { wait_frac: 0.5, min_rank: 2 })
        .run()
        .expect("valid request")
        .into_report();
    assert_eq!(rep.completed(), 1);
    let o = &rep.outcomes[0];
    assert!(o.shed, "WontFit degraded instead of rejected");
    assert!(o.served_rank.unwrap() < 8);
}

#[test]
fn snapshot_serving_pins_pre_append_views_bit_for_bit() {
    // one on-disk container serving while a delta segment is appended
    // mid-trace: jobs arriving before the append instant see the
    // pre-append snapshot, later jobs the appended view — each
    // bit-for-bit against the resident twin of the matching reader view
    let base = synth::uniform(&[60, 50, 40], 8_000, 2);
    let delta = synth::uniform(&[60, 50, 40], 2_000, 77);
    let combined = CooTensor {
        dims: base.dims.clone(),
        coords: base
            .coords
            .iter()
            .zip(&delta.coords)
            .map(|(b, d)| b.iter().chain(d).copied().collect())
            .collect(),
        vals: base.vals.iter().chain(&delta.vals).copied().collect(),
    };
    let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
    let path = {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_serve_snapshot_{}.blco", std::process::id()));
        p
    };
    blco::BlcoStore::write(&BlcoTensor::from_coo_with(&base, cfg), &path).unwrap();

    let mut reg = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg.register_store("t", &path).unwrap();
    assert!(reg.get("t").unwrap().engine.is_oom_for(0, 8), "fixture must stream");

    // id 0 arrives before the append instant (1.0), id 1 after: the run
    // appends up front but binds each job to its arrival's epoch
    let ten = tenants(&[1]);
    let jobs = vec![
        mttkrp_job(0, "t0", "t", 0, 8, 55, 0.0),
        mttkrp_job(1, "t0", "t", 0, 8, 55, 5.0),
    ];
    let rep = ServeRequest::new(&reg)
        .trace(&ten, &jobs)
        .devices(1)
        .threads(1)
        .batching(false)
        .append_at("t", &path, &delta, 1.0)
        .run()
        .expect("valid request")
        .into_report();
    assert_eq!(rep.completed(), 2);
    let bits = |rep: &ServiceReport, id: usize| -> Vec<u64> {
        let o = rep.outcomes.iter().find(|o| o.id == id).unwrap();
        match o.result.as_ref().unwrap() {
            JobResult::Mttkrp(m) => m.data.iter().map(|v| v.to_bits()).collect(),
            JobResult::CpAls(_) => unreachable!(),
        }
    };
    let pre_bits = bits(&rep, 0);
    let post_bits = bits(&rep, 1);
    assert_ne!(pre_bits, post_bits, "the appended nnz must change the answer");

    // resident twins of both reader views, served identically
    let budget = reg.profile().host_mem_bytes;
    let pinned_twin =
        BlcoStoreReader::open_pinned(&path, budget, Some(0)).unwrap().to_tensor().unwrap();
    let full_twin = BlcoStoreReader::open(&path).unwrap().to_tensor().unwrap();
    assert_eq!(pinned_twin.nnz, base.nnz());
    assert_eq!(full_twin.nnz, combined.nnz());
    let mut reg2 = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg2.register_shared("pre", Arc::new(pinned_twin));
    reg2.register_shared("post", Arc::new(full_twin));
    let twin_jobs = vec![
        mttkrp_job(0, "t0", "pre", 0, 8, 55, 0.0),
        mttkrp_job(1, "t0", "post", 0, 8, 55, 0.0),
    ];
    let twin_rep = ServeRequest::new(&reg2)
        .trace(&ten, &twin_jobs)
        .devices(1)
        .threads(1)
        .batching(false)
        .run()
        .expect("valid request")
        .into_report();
    assert_eq!(twin_rep.completed(), 2);
    assert_eq!(bits(&twin_rep, 0), pre_bits, "pre-append view == resident twin");
    assert_eq!(bits(&twin_rep, 1), post_bits, "appended view == resident twin");

    // and both views are numerically the right tensor
    let expect_pre = mttkrp_oracle(&base, 0, &random_factors(&base.dims, 8, 55));
    let expect_post = mttkrp_oracle(&combined, 0, &random_factors(&combined.dims, 8, 55));
    let m = |b: &[u64]| b.iter().map(|&v| f64::from_bits(v)).collect::<Vec<f64>>();
    let diff = |got: &[f64], want: &blco::mttkrp::dense::Matrix| {
        got.iter()
            .zip(&want.data)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f64, f64::max)
    };
    assert!(diff(&m(&pre_bits), &expect_pre) < 1e-9);
    assert!(diff(&m(&post_bits), &expect_post) < 1e-9);
    std::fs::remove_file(&path).ok();
}

#[test]
fn admission_rejections_are_structured_outcomes() {
    let (reg, _, _) = registry();
    let ten = tenants(&[1]);
    let jobs = vec![
        // fine
        mttkrp_job(0, "t0", "hot", 0, 8, 1, 0.0),
        // unknown tensor
        mttkrp_job(1, "t0", "nope", 0, 8, 2, 0.0),
        // rank over the register budget
        mttkrp_job(2, "t0", "hot", 0, MAX_RANK + 1, 3, 0.0),
        // target out of range
        mttkrp_job(3, "t0", "hot", 7, 8, 4, 0.0),
        // rank 0
        mttkrp_job(4, "t0", "hot", 0, 0, 5, 0.0),
    ];
    let rep = serve_batched(&reg, &ten, &jobs, 2, 2);
    assert_eq!(rep.completed(), 1);
    assert_eq!(rep.rejected(), 4);
    for o in &rep.outcomes {
        match (&o.status, o.id) {
            (JobStatus::Completed, 0) => {}
            (JobStatus::Rejected(AdmissionError::UnknownTensor { tensor }), 1) => {
                assert_eq!(tensor, "nope");
            }
            (JobStatus::Rejected(AdmissionError::InvalidRank { rank, max }), 2) => {
                assert_eq!((*rank, *max), (MAX_RANK + 1, MAX_RANK));
            }
            (JobStatus::Rejected(AdmissionError::TargetOutOfRange { target, order }), 3) => {
                assert_eq!((*target, *order), (7, 3));
            }
            (JobStatus::Rejected(AdmissionError::InvalidRank { rank, .. }), 4) => {
                assert_eq!(*rank, 0);
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    // a device too small even for the streaming floor: WontFit, not panic
    let mut starved = TensorRegistry::new(Profile::tiny(4 * 1024));
    starved.register_shared("cold", reg.get("cold").unwrap().engine.tensor());
    let job = vec![mttkrp_job(0, "t0", "cold", 0, 8, 6, 0.0)];
    let rep = serve_batched(&starved, &ten, &job, 1, 2);
    assert_eq!(rep.rejected(), 1);
    match &rep.outcomes[0].status {
        JobStatus::Rejected(AdmissionError::WontFit { floor_bytes, budget_bytes, .. }) => {
            assert!(floor_bytes > budget_bytes);
        }
        other => panic!("expected WontFit, got {other:?}"),
    }
}

#[test]
fn one_payload_serves_every_registry_and_cpals_jobs_route_through_it() {
    let (reg, _, cold) = registry();
    let shared: Arc<BlcoTensor> = reg.get("cold").unwrap().engine.tensor();
    let before = Arc::strong_count(&shared);
    let mut reg2 = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg2.register_shared("cold", Arc::clone(&shared));
    assert_eq!(Arc::strong_count(&shared), before + 1, "engine shares the Arc");
    assert!(Arc::ptr_eq(&reg2.get("cold").unwrap().engine.tensor(), &shared));

    // a CP-ALS job through the service: admitted (streamed), completed,
    // report carried back with mode traces and plan reuse
    let ten = tenants(&[1]);
    let jobs = vec![JobRequest::new(
        0,
        "t0",
        "cold",
        JobKind::CpAls { rank: 4, iters: 3, seed: 9 },
        0.0,
    )];
    let rep = serve_batched(&reg2, &ten, &jobs, 1, 4);
    assert_eq!(rep.completed(), 1);
    let o = &rep.outcomes[0];
    assert_eq!(o.route, Some(Route::Streamed));
    match o.result.as_ref().unwrap() {
        JobResult::CpAls(als) => {
            assert_eq!(als.fits.len(), 3);
            assert_eq!(als.mode_traces.len(), cold.order());
            // one plan per mode, reused across iterations
            assert_eq!(rep.schedule.built, cold.order());
            assert_eq!(rep.schedule.hits, cold.order() * 2);
            assert!(als.fits.iter().all(|&f| f <= 1.0 + 1e-9));
        }
        JobResult::Mttkrp(_) => panic!("expected a CP-ALS result"),
    }
    assert!(o.duration_s > 0.0);
}

#[test]
fn disk_backed_tensor_serves_jobs_identical_to_resident() {
    // the same container-backed tensor registered next to its resident
    // twin must serve every job with bit-identical results, while the
    // block cache keeps host residency under its budget
    let (reg, _hot, cold) = registry();
    let cold_payload = reg.get("cold").unwrap().engine.tensor();
    let path = {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_serve_disk_{}.blco", std::process::id()));
        p
    };
    blco::BlcoStore::write(&cold_payload, &path).unwrap();

    let budget = 4 * 512 * 16;
    let mut reg2 =
        TensorRegistry::new(Profile::tiny(48 * 1024).with_host_memory(budget));
    reg2.register_shared("resident", Arc::clone(&cold_payload));
    reg2.register_store("disk", &path).unwrap();

    // same trace against both names: fused streamed groups on each
    let ten = tenants(&[1, 1]);
    let mut jobs = Vec::new();
    for (i, tensor) in ["resident", "disk", "resident", "disk"].into_iter().enumerate() {
        for k in 0..3usize {
            jobs.push(mttkrp_job(i * 3 + k, &format!("t{}", i % 2), tensor, 0, 8, 77, 0.0));
        }
    }
    let rep = serve_batched(&reg2, &ten, &jobs, 1, 1);
    assert_eq!(rep.completed(), jobs.len());
    assert_eq!(rep.rejected(), 0);

    // every identical (seed, mode, rank) job must produce identical bits
    // regardless of which tier served it
    let mut reference: Option<Vec<u64>> = None;
    for o in &rep.outcomes {
        match o.result.as_ref().unwrap() {
            JobResult::Mttkrp(m) => {
                let bits: Vec<u64> = m.data.iter().map(|v| v.to_bits()).collect();
                match &reference {
                    None => reference = Some(bits),
                    Some(r) => assert_eq!(&bits, r, "job {} diverged", o.id),
                }
            }
            JobResult::CpAls(_) => panic!("trace is MTTKRP-only"),
        }
    }
    // oracle correctness of the shared answer
    let expect = mttkrp_oracle(&cold, 0, &random_factors(&cold.dims, 8, 77));
    if let JobResult::Mttkrp(m) = rep.outcomes[0].result.as_ref().unwrap() {
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    let stats = reg2.get("disk").unwrap().engine.host_cache_stats().unwrap();
    assert!(stats.peak_resident_bytes <= budget, "cache broke its budget");
    assert!(stats.misses > 0, "disk tier actually read from disk");
    assert!(reg2.disk_bytes() > 0);
    std::fs::remove_file(&path).ok();
}
