//! Integration over the multi-device cluster streamer, driven through the
//! [`StreamRequest`] front door: sharded results match the single-device
//! path and the serial oracle on every mode for D ∈ {1, 2, 4} (D = 1
//! requests route to the single-device pipeline); the degenerate D = 1
//! *cluster body* — still reachable through the deprecated wrapper —
//! reproduces the stream report; greedy placement is never worse than
//! round-robin on modelled makespan (and strictly better on skewed
//! costs); merge traffic is charged to the counters.

use blco::coordinator::cluster::{
    estimate_batch_cost, modelled_makespan, plan_placement, ClusterReport, Placement,
};
use blco::device::{Counters, LinkTopology, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::tensor::synth;
use blco::{StreamOutcome, StreamRequest};

fn batched_engine(devices: usize, links: LinkTopology) -> (blco::CooTensor, BlcoEngine) {
    let t = synth::fiber_clustered(&[60, 50, 40], 9_000, 2, 1.0, 41);
    let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 64, threads: 2, ..Default::default() };
    let b = BlcoTensor::from_coo_with(&t, cfg);
    assert!(b.batches.len() > 4, "need a long pipeline");
    let prof = Profile::tiny(1 << 16).with_devices(devices).with_links(links);
    let eng = BlcoEngine::new(b, prof);
    (t, eng)
}

/// One request with the engine's own device count: Streamed for a
/// single-device profile, Clustered otherwise.
fn run(
    eng: &BlcoEngine,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    counters: &Counters,
) -> StreamOutcome {
    StreamRequest::new(eng, target)
        .job(factors)
        .threads(4)
        .counters(counters)
        .run(std::slice::from_mut(out))
        .unwrap()
}

/// [`run`] on a multi-device profile, unwrapped to its cluster report.
fn run_cluster(
    eng: &BlcoEngine,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    counters: &Counters,
) -> ClusterReport {
    run(eng, target, factors, out, counters).into_clustered().unwrap()
}

#[test]
fn sharded_matches_oracle_all_modes_and_device_counts() {
    for links in [LinkTopology::Shared, LinkTopology::Dedicated] {
        for devices in [1usize, 2, 4] {
            let (t, eng) = batched_engine(devices, links);
            let factors = random_factors(&t.dims, 8, 5);
            for target in 0..3 {
                let expect = mttkrp_oracle(&t, target, &factors);
                let mut out = Matrix::zeros(t.dims[target] as usize, 8);
                let outcome = run(&eng, target, &factors, &mut out, &Counters::new());
                assert!(
                    out.max_abs_diff(&expect) < 1e-9,
                    "links {links:?} D={devices} mode {target}"
                );
                match outcome {
                    // a one-device request routes to the single-device
                    // pipeline — no shard plan to check
                    StreamOutcome::Streamed(rep) => {
                        assert_eq!(devices, 1);
                        assert_eq!(rep.batches.len(), eng.num_batches());
                    }
                    StreamOutcome::Clustered(rep) => {
                        assert!(devices > 1);
                        assert_eq!(rep.devices, devices);
                        assert_eq!(rep.batches.len(), eng.num_batches());
                        // every batch placed exactly once
                        let mut seen = vec![false; eng.num_batches()];
                        for tl in &rep.per_device {
                            for &b in &tl.batches {
                                assert!(!seen[b], "batch {b} on two devices");
                                seen[b] = true;
                            }
                        }
                        assert!(seen.iter().all(|&s| s), "some batch unplaced");
                        assert!(rep.imbalance() >= 1.0 - 1e-12);
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_matches_single_device_result() {
    let (t, eng1) = batched_engine(1, LinkTopology::Shared);
    let (_, eng4) = batched_engine(4, LinkTopology::Dedicated);
    let factors = random_factors(&t.dims, 16, 7);
    for target in 0..3 {
        let mut a = Matrix::zeros(t.dims[target] as usize, 16);
        let mut b = Matrix::zeros(t.dims[target] as usize, 16);
        run(&eng1, target, &factors, &mut a, &Counters::new());
        run_cluster(&eng4, target, &factors, &mut b, &Counters::new());
        assert!(a.max_abs_diff(&b) < 1e-9, "mode {target}");
    }
}

#[test]
#[allow(deprecated)] // pins the legacy D = 1 cluster body against the stream path
fn degenerate_single_device_reproduces_stream_report() {
    use blco::coordinator::cluster::cluster_mttkrp;

    let (t, eng) = batched_engine(1, LinkTopology::Shared);
    let factors = random_factors(&t.dims, 8, 9);
    let mut a = Matrix::zeros(t.dims[0] as usize, 8);
    let mut b = Matrix::zeros(t.dims[0] as usize, 8);
    let sr = run(&eng, 0, &factors, &mut a, &Counters::new())
        .into_streamed()
        .unwrap();
    let cr = cluster_mttkrp(&eng, 0, &factors, &mut b, 4, &Counters::new());

    assert_eq!(cr.devices, 1);
    assert_eq!(cr.merge_bytes, 0, "no merge traffic with one device");
    assert_eq!(cr.merge_s, 0.0);
    assert_eq!(cr.batches.len(), sr.batches.len());
    assert_eq!(cr.bytes, sr.bytes);
    // identical pipeline model → identical modelled times (same float ops)
    let close = |x: f64, y: f64| (x - y).abs() <= 1e-12 * x.abs().max(y.abs()).max(1e-30);
    assert!(close(cr.stream_s, sr.overall_s), "{} vs {}", cr.stream_s, sr.overall_s);
    assert!(close(cr.overall_s, sr.overall_s));
    assert!(close(cr.transfer_s, sr.transfer_s));
    assert!(close(cr.compute_s, sr.compute_s));
    for (cb, sb) in cr.batches.iter().zip(&sr.batches) {
        assert_eq!(cb.bytes, sb.bytes);
        assert!(close(cb.transfer_s, sb.transfer_s));
        assert!(close(cb.compute_s, sb.compute_s));
    }
    // and the same numbers out (up to atomic-accumulation reordering
    // across threads, which is not deterministic between runs)
    assert!(a.max_abs_diff(&b) < 1e-9);
}

#[test]
fn greedy_beats_round_robin_on_skewed_costs() {
    // synthetic heavy-tailed batch costs: one giant batch + a long tail —
    // the hypersparse regime where naive round-robin stacks light batches
    // behind the heavy one
    let mut costs = vec![1.0f64; 31];
    costs[0] = 10.0;
    for (i, c) in costs.iter_mut().enumerate().skip(1) {
        *c = 1.0 + (i % 5) as f64 * 0.5;
    }
    for devices in [2usize, 4] {
        let g = plan_placement(&costs, devices, Placement::Greedy);
        let r = plan_placement(&costs, devices, Placement::RoundRobin);
        let mg = modelled_makespan(&costs, &g, devices);
        let mr = modelled_makespan(&costs, &r, devices);
        assert!(mg < mr, "D={devices}: greedy {mg} vs round-robin {mr}");
    }
}

#[test]
fn greedy_meets_list_scheduling_bound_on_real_batches() {
    // Graham's list-scheduling guarantee holds against the *computable*
    // lower bound: when the last-finishing batch was placed, its device
    // had the least load ≤ (total − c)/D, so
    // makespan ≤ total/D + cmax — for greedy under any order, hence for
    // LPT. (The 4/3·OPT bound cannot be checked without OPT itself;
    // the strict greedy-vs-round-robin win on skew is asserted above.)
    let (_, eng) = batched_engine(4, LinkTopology::Dedicated);
    let costs: Vec<f64> = (0..eng.num_batches())
        .map(|b| estimate_batch_cost(&eng, b, 0, 16))
        .collect();
    assert!(costs.iter().all(|&c| c > 0.0));
    let g = plan_placement(&costs, 4, Placement::Greedy);
    let mg = modelled_makespan(&costs, &g, 4);
    let total: f64 = costs.iter().sum();
    let cmax = costs.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(
        mg <= total / 4.0 + cmax + 1e-12,
        "greedy {mg} exceeds the list-scheduling bound {}",
        total / 4.0 + cmax
    );
    // and it is never worse than putting everything on one device
    assert!(mg <= total + 1e-12);
}

#[test]
fn placement_policy_does_not_change_the_answer() {
    let (t, eng) = batched_engine(4, LinkTopology::Shared);
    let factors = random_factors(&t.dims, 8, 13);
    let expect = mttkrp_oracle(&t, 1, &factors);
    for placement in [Placement::Greedy, Placement::RoundRobin] {
        let mut out = Matrix::zeros(t.dims[1] as usize, 8);
        let rep = StreamRequest::new(&eng, 1)
            .job(&factors)
            .placement(placement)
            .threads(4)
            .run(std::slice::from_mut(&mut out))
            .unwrap()
            .into_clustered()
            .unwrap();
        assert_eq!(rep.placement, placement);
        assert!(out.max_abs_diff(&expect) < 1e-9, "{placement:?}");
    }
}

#[test]
fn merge_traffic_charged_and_modelled() {
    let (t, eng2) = batched_engine(2, LinkTopology::Shared);
    let (_, eng1) = batched_engine(1, LinkTopology::Shared);
    let factors = random_factors(&t.dims, 8, 15);
    let (c1, c2) = (Counters::new(), Counters::new());
    let mut a = Matrix::zeros(t.dims[0] as usize, 8);
    let mut b = Matrix::zeros(t.dims[0] as usize, 8);
    // one device streams with no merge; its counters are the baseline
    run(&eng1, 0, &factors, &mut a, &c1).into_streamed().unwrap();
    let r2 = run_cluster(&eng2, 0, &factors, &mut b, &c2);
    // one reduction round: one output-sized segment over the peer link
    let seg = t.dims[0] as usize * 8 * 8;
    assert_eq!(r2.merge_bytes, seg);
    assert!(r2.merge_s > 0.0);
    assert!((r2.overall_s - (r2.stream_s + r2.merge_s)).abs() < 1e-15);
    // the merge's reads/writes land in the counters
    let extra = c2.snapshot().volume_bytes() as i64 - c1.snapshot().volume_bytes() as i64;
    assert_eq!(extra, (seg * 3) as i64, "merge reads 2 partials, writes 1");
}

#[test]
fn four_devices_on_two_link_ports() {
    // regression: the host-link array used to be indexed with the raw
    // device id whenever links > 1, so any profile with
    // 1 < host_links() < devices walked off the end. Devices now
    // round-robin over the ports (`device % links`).
    let (t, eng) = batched_engine(4, LinkTopology::Ports(2));
    assert_eq!(eng.profile.host_links(), 2);
    let factors = random_factors(&t.dims, 8, 19);
    for target in 0..3 {
        let expect = mttkrp_oracle(&t, target, &factors);
        let mut out = Matrix::zeros(t.dims[target] as usize, 8);
        let rep = run_cluster(&eng, target, &factors, &mut out, &Counters::new());
        assert!(out.max_abs_diff(&expect) < 1e-9, "mode {target}");
        assert_eq!(rep.devices, 4);
        assert_eq!(rep.batches.len(), eng.num_batches());
    }
    // two ports sit between the one-shared-link and four-dedicated-link
    // extremes on modelled streaming makespan
    let (_, shared) = batched_engine(4, LinkTopology::Shared);
    let (_, dedicated) = batched_engine(4, LinkTopology::Dedicated);
    let mut o1 = Matrix::zeros(t.dims[0] as usize, 8);
    let mut o2 = Matrix::zeros(t.dims[0] as usize, 8);
    let mut o3 = Matrix::zeros(t.dims[0] as usize, 8);
    let rp = run_cluster(&eng, 0, &factors, &mut o1, &Counters::new());
    let rs = run_cluster(&shared, 0, &factors, &mut o2, &Counters::new());
    let rd = run_cluster(&dedicated, 0, &factors, &mut o3, &Counters::new());
    assert!(
        rp.stream_s <= rs.stream_s * (1.0 + 1e-9),
        "2 ports {} vs shared {}",
        rp.stream_s,
        rs.stream_s
    );
    assert!(
        rp.stream_s >= rd.stream_s * (1.0 - 1e-9),
        "2 ports {} vs dedicated {}",
        rp.stream_s,
        rd.stream_s
    );
}

#[test]
fn dedicated_links_never_slower_than_shared() {
    let (t, shared) = batched_engine(4, LinkTopology::Shared);
    let (_, dedicated) = batched_engine(4, LinkTopology::Dedicated);
    let factors = random_factors(&t.dims, 8, 17);
    let mut a = Matrix::zeros(t.dims[0] as usize, 8);
    let mut b = Matrix::zeros(t.dims[0] as usize, 8);
    let rs = run_cluster(&shared, 0, &factors, &mut a, &Counters::new());
    let rd = run_cluster(&dedicated, 0, &factors, &mut b, &Counters::new());
    assert!(
        rd.stream_s <= rs.stream_s * (1.0 + 1e-9),
        "dedicated {} vs shared {}",
        rd.stream_s,
        rs.stream_s
    );
    // four host links: per-link occupancy is a fraction of the shared case
    let occ_shared = rs.link_occupancy(&shared.profile);
    let occ_dedicated = rd.link_occupancy(&dedicated.profile);
    assert!(occ_shared > 0.0 && occ_shared <= 1.0);
    assert!(occ_dedicated > 0.0 && occ_dedicated <= 1.0);
}
