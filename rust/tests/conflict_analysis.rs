//! Analyzer-vs-reality property suite: on randomized synthetic tensors
//! (uniform and fiber-skewed, empty slices, single-nnz blocks) the static
//! conflict certificates must agree *exactly* with what the instrumented
//! race checker observes, and certified schedules must reproduce the
//! sequential kernel bit for bit.

use std::sync::Arc;

use blco::analysis::conflict::{analyze_mode, CertificateSet, SyncClass};
use blco::analysis::racecheck::{racecheck, run_waved};
use blco::device::{Counters, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::mttkrp::blco::{BlcoEngine, Resolution};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::mttkrp::Mttkrp;
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;
use blco::util::prop::{check, Config, Ctx};

/// Random tensor for one property case: dims scale with the size hint,
/// half the cases are fiber-skewed (Zipf theta up to ~1.3), and dims are
/// deliberately allowed to exceed nnz so empty slices occur.
fn random_tensor(ctx: &mut Ctx) -> CooTensor {
    let dims: Vec<u64> =
        (0..3).map(|_| 4 + ctx.rng.below(4 * ctx.size as u64 + 8)).collect();
    let nnz = 50 + ctx.rng.below(30 * ctx.size as u64) as usize;
    let seed = ctx.rng.next_u64();
    if ctx.rng.below(2) == 0 {
        let theta = 0.5 + ctx.rng.f64() * 0.8;
        let mode = ctx.rng.below(3) as usize;
        synth::fiber_clustered(&dims, nnz, mode, theta, seed)
    } else {
        synth::uniform(&dims, nnz, seed)
    }
}

fn random_config(ctx: &mut Ctx) -> BlcoConfig {
    BlcoConfig {
        max_block_nnz: 1 << (5 + ctx.rng.below(5)), // 32..512
        workgroup: 1 << (3 + ctx.rng.below(4)),     // 8..64
        ..Default::default()
    }
}

fn engine(t: &CooTensor, cfg: BlcoConfig) -> BlcoEngine {
    BlcoEngine::new(BlcoTensor::from_coo_with(t, cfg), Profile::a100())
}

#[test]
fn racecheck_agrees_with_static_analysis_on_random_tensors() {
    check(
        "racecheck_exact",
        Config { cases: 14, max_size: 28, ..Default::default() },
        |ctx| {
            let t = random_tensor(ctx);
            let eng = engine(&t, random_config(ctx));
            let set = CertificateSet::analyze(&eng.src);
            let rank = 1 << (1 + ctx.rng.below(3)); // 2..8
            let factors = random_factors(&t.dims, rank, ctx.rng.next_u64());
            for m in 0..3 {
                let rep = racecheck(&eng, set.mode(m), &factors, 4);
                if !rep.missed_static.is_empty() {
                    return Err(format!(
                        "mode {m}: write log contains {} conflicts the \
                         analysis missed, e.g. {:?}",
                        rep.missed_static.len(),
                        rep.missed_static[0]
                    ));
                }
                if !rep.stale_static.is_empty() {
                    return Err(format!(
                        "mode {m}: {} certified edges never observed",
                        rep.stale_static.len()
                    ));
                }
                if !rep.races.is_empty() {
                    return Err(format!(
                        "mode {m}: waved run raced: {:?}",
                        rep.races[0]
                    ));
                }
                if !rep.bit_identical {
                    return Err(format!(
                        "mode {m}: waved output is not bit-for-bit the \
                         sequential result"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn certificates_satisfy_structural_invariants() {
    check(
        "cert_invariants",
        Config { cases: 16, max_size: 32, ..Default::default() },
        |ctx| {
            let t = random_tensor(ctx);
            let eng = engine(&t, random_config(ctx));
            for m in 0..3 {
                let cert = analyze_mode(&eng.src, m, &Counters::new());
                for b in &cert.batches {
                    // NoSync ⇔ empty overlap graph
                    if (b.recommendation == SyncClass::NoSync) != b.edges.is_empty() {
                        return Err(format!(
                            "mode {m} batch {}: NoSync/edges mismatch",
                            b.batch
                        ));
                    }
                    // order-preserving coloring: every edge crosses waves
                    // forward
                    for &(i, j) in &b.edges {
                        if b.wave_of[i as usize] >= b.wave_of[j as usize] {
                            return Err(format!(
                                "mode {m} batch {}: edge ({i},{j}) not \
                                 wave-ordered",
                                b.batch
                            ));
                        }
                    }
                    let covered: usize =
                        b.wave_members().iter().map(Vec::len).sum();
                    if covered != b.wgs {
                        return Err(format!(
                            "mode {m} batch {}: waves cover {covered} of {} wgs",
                            b.batch, b.wgs
                        ));
                    }
                }
                let nnz: usize = cert.blocks.iter().map(|b| b.nnz).sum();
                if nnz != eng.src.nnz() {
                    return Err(format!(
                        "mode {m}: block reports cover {nnz} of {} nnz",
                        eng.src.nnz()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn auto_is_always_concrete_and_certified_engines_match_the_oracle() {
    check(
        "auto_concrete",
        Config { cases: 10, max_size: 24, ..Default::default() },
        |ctx| {
            let t = random_tensor(ctx);
            let eng = engine(&t, random_config(ctx));
            let set = Arc::new(CertificateSet::analyze(&eng.src));
            let eng = eng.with_certificates(set);
            let rank = 4;
            let factors = random_factors(&t.dims, rank, ctx.rng.next_u64());
            for m in 0..3 {
                let res = eng.effective_resolution(m);
                if res == Resolution::Auto {
                    return Err(format!("mode {m}: Auto leaked past resolution"));
                }
                let mut out = Matrix::zeros(t.dims[m] as usize, rank);
                eng.mttkrp(m, &factors, &mut out, 4, &Counters::new());
                let expect = mttkrp_oracle(&t, m, &factors);
                let diff = out.max_abs_diff(&expect);
                if diff > 1e-9 {
                    return Err(format!(
                        "mode {m} ({res:?}): certified engine off by {diff:e}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn single_nnz_blocks_certify_and_replay() {
    // max_block_nnz = 1: every block holds one non-zero, every work-group
    // is a single flush — the degenerate end of the blocking spectrum
    let t = synth::uniform(&[12, 9, 7], 300, 99);
    let cfg = BlcoConfig { max_block_nnz: 1, workgroup: 8, ..Default::default() };
    let eng = engine(&t, cfg);
    let set = CertificateSet::analyze(&eng.src);
    let factors = random_factors(&t.dims, 4, 101);
    for m in 0..3 {
        let cert = set.mode(m);
        for b in &cert.blocks {
            assert_eq!(b.nnz, 1);
            assert_eq!(b.rows, 1);
            assert_eq!(b.max_fiber_degree, 1);
        }
        let rep = racecheck(&eng, cert, &factors, 4);
        assert!(rep.ok(), "mode {m}: {rep:?}");
    }
}

#[test]
fn empty_slices_and_tiny_nnz_are_handled() {
    // dims far larger than nnz: most slices in every mode are empty
    let t = synth::uniform(&[500, 400, 300], 60, 7);
    let cfg = BlcoConfig { max_block_nnz: 16, workgroup: 8, ..Default::default() };
    let eng = engine(&t, cfg);
    let set = CertificateSet::analyze(&eng.src);
    let factors = random_factors(&t.dims, 4, 9);
    let mut nosync = 0;
    for m in 0..3 {
        let rep = racecheck(&eng, set.mode(m), &factors, 2);
        assert!(rep.ok(), "mode {m}: {rep:?}");
        nosync += set.mode(m).no_sync_batches();
    }
    // a tensor this sparse must certify synchronization-free work somewhere
    assert!(nosync > 0);
}

#[test]
fn waved_execution_is_deterministic_across_thread_counts() {
    // the order-preserving coloring makes the waved run independent of the
    // number of worker threads — every thread count replays the same
    // per-row flush order
    let t = synth::fiber_clustered(&[40, 200, 180], 5_000, 0, 1.0, 21);
    let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 32, ..Default::default() };
    let eng = engine(&t, cfg);
    let set = CertificateSet::analyze(&eng.src);
    let factors = random_factors(&t.dims, 8, 23);
    let cert = set.mode(0);
    let mut reference = Matrix::zeros(40, 8);
    run_waved(&eng, cert, &factors, &mut reference, 1, &Counters::new(), None);
    for threads in [2usize, 4, 8] {
        let mut out = Matrix::zeros(40, 8);
        run_waved(&eng, cert, &factors, &mut out, threads, &Counters::new(), None);
        assert!(
            out.data
                .iter()
                .zip(&reference.data)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "{threads} threads diverged from the single-threaded waved run"
        );
    }
}
