//! Container v2 end-to-end, public API only: version-1 files still open
//! and compute identically; the codec matrix (none / delta-varint /
//! shuffled) streams bit-for-bit across the resident and disk tiers
//! through [`StreamRequest`]; append + in-place compaction produces the
//! byte-identical file a scratch rebuild would; a flipped bit in a
//! compressed payload is a structured checksum error, never a panic.

use std::path::{Path, PathBuf};

use blco::device::{Counters, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::{
    crc32, BlcoStore, BlcoStoreReader, BlcoStoreWriter, Codec, StoreError,
    STORE_MAGIC,
};
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::mttkrp::Mttkrp;
use blco::tensor::coo::CooTensor;
use blco::tensor::{ooc, synth};
use blco::util::pool::ExecBackend;
use blco::StreamRequest;

fn tmpfile(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("blco_v2_{}_{}", std::process::id(), name));
    p
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn sample() -> (CooTensor, BlcoTensor) {
    let t = synth::uniform(&[60, 50, 40], 8_000, 11);
    let cfg = BlcoConfig {
        max_block_nnz: 512,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    };
    let b = BlcoTensor::from_coo_with(&t, cfg);
    assert!(b.batches.len() > 4, "need a real batch pipeline");
    (t, b)
}

/// Hand-write `b` in the version-1 layout (raw payloads, 20-byte index
/// entries, no codec column, no segments) — the compat corpus, since this
/// build only writes version 2.
fn write_v1(b: &BlcoTensor, path: &Path) {
    let mut header: Vec<u8> = Vec::new();
    header.extend_from_slice(&(b.dims().len() as u32).to_le_bytes());
    for &d in b.dims() {
        header.extend_from_slice(&d.to_le_bytes());
    }
    header.extend_from_slice(&(b.nnz as u64).to_le_bytes());
    header.extend_from_slice(&b.norm().to_le_bytes());
    header.extend_from_slice(&(b.config.max_block_nnz as u64).to_le_bytes());
    header.extend_from_slice(&(b.config.workgroup as u32).to_le_bytes());
    header.extend_from_slice(&b.config.inblock_budget.to_le_bytes());
    header.extend_from_slice(&(b.blocks.len() as u64).to_le_bytes());
    let payload_of = |blk: &blco::format::blco::Block| {
        let mut buf = Vec::with_capacity(blk.nnz() * 16);
        for &l in &blk.lidx {
            buf.extend_from_slice(&l.to_le_bytes());
        }
        for &v in &blk.vals {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        buf
    };
    for blk in &b.blocks {
        let buf = payload_of(blk.as_ref());
        header.extend_from_slice(&blk.key.to_le_bytes());
        header.extend_from_slice(&(blk.nnz() as u64).to_le_bytes());
        header.extend_from_slice(&crc32(&buf).to_le_bytes());
    }
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(&STORE_MAGIC);
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(header.len() as u64).to_le_bytes());
    out.extend_from_slice(&header);
    out.extend_from_slice(&crc32(&header).to_le_bytes());
    for blk in &b.blocks {
        out.extend_from_slice(&payload_of(blk.as_ref()));
    }
    std::fs::write(path, &out).unwrap();
}

fn concat(a: &CooTensor, b: &CooTensor) -> CooTensor {
    let mut c = CooTensor::new(&a.dims);
    for e in 0..a.nnz() {
        c.push(&a.coord(e), a.vals[e]);
    }
    for e in 0..b.nnz() {
        c.push(&b.coord(e), b.vals[e]);
    }
    c
}

// a budget of ~4 small decompressed blocks: full passes must evict
const TIGHT_BUDGET: usize = 4 * 512 * 16;

#[test]
fn v1_container_reads_and_computes_like_v2() {
    let (t, b) = sample();
    let p1 = tmpfile("v1.blco");
    let p2 = tmpfile("v1_as_v2.blco");
    write_v1(&b, &p1);
    BlcoStore::write(&b, &p2).unwrap();

    let r1 = BlcoStoreReader::open(&p1).unwrap();
    assert_eq!(r1.version(), 1);
    assert_eq!(r1.default_codec(), Codec::None);
    assert_eq!(r1.segments(), 0, "v1 has no delta segments");
    assert!((r1.compression_ratio() - 1.0).abs() < 1e-12, "v1 stores raw");
    // v1 stores raw payloads, so the scanned (stored) bytes are nnz * 16
    assert_eq!(r1.verify_payloads().unwrap(), b.nnz * 16);
    let r2 = BlcoStoreReader::open(&p2).unwrap();
    assert_eq!(r2.version(), 2);
    assert_eq!(r1.nnz(), r2.nnz());
    assert_eq!(r1.num_blocks(), r2.num_blocks());

    // identical decoded blocks → identical kernel input → identical bits
    let e1 = BlcoEngine::from_store_reader(r1, Profile::a100());
    let e2 = BlcoEngine::from_store_reader(r2, Profile::a100());
    let factors = random_factors(&t.dims, 8, 5);
    for target in 0..t.order() {
        let mut a = Matrix::zeros(t.dims[target] as usize, 8);
        let mut d = Matrix::zeros(t.dims[target] as usize, 8);
        e1.mttkrp(target, &factors, &mut a, 1, &Counters::new());
        e2.mttkrp(target, &factors, &mut d, 1, &Counters::new());
        assert_eq!(bits(&a), bits(&d), "v1 vs v2 mode {target}");
        let expect = mttkrp_oracle(&t, target, &factors);
        assert!(a.max_abs_diff(&expect) < 1e-9, "mode {target}");
    }
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn codec_matrix_streams_bit_for_bit_across_tiers() {
    let (t, b) = sample();
    let factors = random_factors(&t.dims, 8, 7);
    let prof = Profile::tiny(1 << 16);
    let resident = BlcoEngine::new(b.clone(), prof.clone());
    for codec in [Codec::None, Codec::DeltaVarint, Codec::Shuffled] {
        let p = tmpfile(&format!("codec_{}.blco", codec.name()));
        let summary = BlcoStore::write_with(&b, &p, codec).unwrap();
        let reader = BlcoStoreReader::open_with_budget(&p, TIGHT_BUDGET).unwrap();
        assert_eq!(reader.default_codec(), codec);
        assert!(reader.compression_ratio() >= 1.0 - 1e-12);
        if codec == Codec::DeltaVarint {
            assert!(
                reader.compression_ratio() > 1.0,
                "delta-varint must shrink sorted lidx streams"
            );
            assert!(summary.stored_bytes < summary.payload_bytes);
        }
        let disk = BlcoEngine::from_store_reader(reader, prof.clone());
        for target in 0..t.order() {
            // threads = 1: a fully deterministic float-op order, so the
            // two tiers must agree to the bit
            let mut a = Matrix::zeros(t.dims[target] as usize, 8);
            let mut d = Matrix::zeros(t.dims[target] as usize, 8);
            let ra = StreamRequest::new(&resident, target)
                .job(&factors)
                .devices(1)
                .threads(1)
                .run(std::slice::from_mut(&mut a))
                .unwrap()
                .into_streamed()
                .unwrap();
            let rd = StreamRequest::new(&disk, target)
                .job(&factors)
                .devices(1)
                .threads(1)
                .run(std::slice::from_mut(&mut d))
                .unwrap()
                .into_streamed()
                .unwrap();
            assert_eq!(bits(&a), bits(&d), "{codec:?} mode {target}");
            // wire bytes are decompressed bytes on both tiers: the same
            // plan, clock and volume regardless of the stored codec
            assert_eq!(ra.bytes, rd.bytes, "{codec:?} mode {target}");
            assert_eq!(ra.transfer_s, rd.transfer_s);

            // threads = 4: atomic accumulation reorders across runs, so
            // parity is numeric; the modelled traffic stays exact
            let mut a4 = Matrix::zeros(t.dims[target] as usize, 8);
            let mut d4 = Matrix::zeros(t.dims[target] as usize, 8);
            let ra4 = StreamRequest::new(&resident, target)
                .job(&factors)
                .devices(1)
                .threads(4)
                .run(std::slice::from_mut(&mut a4))
                .unwrap()
                .into_streamed()
                .unwrap();
            let rd4 = StreamRequest::new(&disk, target)
                .job(&factors)
                .devices(1)
                .threads(4)
                .run(std::slice::from_mut(&mut d4))
                .unwrap()
                .into_streamed()
                .unwrap();
            assert_eq!(ra4.bytes, rd4.bytes);
            let expect = mttkrp_oracle(&t, target, &factors);
            assert!(a4.max_abs_diff(&expect) < 1e-9, "{codec:?} mode {target}");
            assert!(d4.max_abs_diff(&expect) < 1e-9, "{codec:?} mode {target}");
        }
        let stats = disk.src.reader().unwrap().cache_stats();
        assert!(
            stats.peak_resident_bytes <= TIGHT_BUDGET,
            "{codec:?}: peak {} > budget {TIGHT_BUDGET}",
            stats.peak_resident_bytes
        );
        assert!(stats.misses > 0, "{codec:?}: streaming must read from disk");
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn append_then_compact_is_byte_identical_to_a_scratch_rebuild() {
    let base = synth::uniform(&[48, 40, 32], 5_000, 3);
    let delta = synth::uniform(&[48, 40, 32], 1_500, 9);
    let whole = concat(&base, &delta);
    let cfg = BlcoConfig {
        max_block_nnz: 512,
        workgroup: 64,
        threads: 2,
        ..Default::default()
    };
    for codec in [Codec::None, Codec::DeltaVarint] {
        let p = tmpfile(&format!("appended_{}.blco", codec.name()));
        let p2 = tmpfile(&format!("scratch_{}.blco", codec.name()));
        BlcoStore::write_with(&BlcoTensor::from_coo_with(&base, cfg), &p, codec)
            .unwrap();

        let sum = BlcoStoreWriter::append(&p, &delta, None).unwrap();
        assert_eq!(sum.appended_nnz, delta.nnz());
        assert_eq!(sum.segments, 1);
        {
            let r = BlcoStoreReader::open(&p).unwrap();
            assert_eq!(r.segments(), 1);
            assert_eq!(r.nnz(), whole.nnz());
            assert!(r.read_amplification() > 1.0, "a pending segment costs reads");
        }

        // in-place compaction folds the segment into a fresh base...
        ooc::compact(&p, None, ExecBackend::from_threads(2), None).unwrap();
        // ...and the result is the byte-for-byte file a from-scratch
        // build over the concatenated tensor produces
        BlcoStore::write_with(&BlcoTensor::from_coo_with(&whole, cfg), &p2, codec)
            .unwrap();
        assert_eq!(
            std::fs::read(&p).unwrap(),
            std::fs::read(&p2).unwrap(),
            "{codec:?}: compacted container != scratch rebuild"
        );

        let ra = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(ra.segments(), 0);
        assert!((ra.read_amplification() - 1.0).abs() < 1e-12);
        drop(ra);

        // the compacted container streams the concatenated answer, and
        // bitwise the same answer as an engine over the scratch file
        let prof = Profile::tiny(1 << 16);
        let ea = BlcoEngine::from_store_reader(
            BlcoStoreReader::open_with_budget(&p, TIGHT_BUDGET).unwrap(),
            prof.clone(),
        );
        let eb = BlcoEngine::from_store_reader(
            BlcoStoreReader::open_with_budget(&p2, TIGHT_BUDGET).unwrap(),
            prof,
        );
        let factors = random_factors(&whole.dims, 8, 13);
        let expect = mttkrp_oracle(&whole, 0, &factors);
        let mut a = Matrix::zeros(whole.dims[0] as usize, 8);
        let mut d = Matrix::zeros(whole.dims[0] as usize, 8);
        for (eng, out) in [(&ea, &mut a), (&eb, &mut d)] {
            StreamRequest::new(eng, 0)
                .job(&factors)
                .devices(1)
                .threads(1)
                .run(std::slice::from_mut(out))
                .unwrap();
        }
        assert!(a.max_abs_diff(&expect) < 1e-9, "{codec:?}");
        assert_eq!(bits(&a), bits(&d), "{codec:?}");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&p2).ok();
    }
}

#[test]
fn corrupted_compressed_payload_is_a_checksum_error() {
    let (_t, b) = sample();
    for codec in [Codec::DeltaVarint, Codec::Shuffled] {
        let p = tmpfile(&format!("corrupt_{}.blco", codec.name()));
        BlcoStore::write_with(&b, &p, codec).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flip one bit in the last stored (compressed) payload byte: the
        // header stays pristine, so only the per-block payload checksum
        // can catch it
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();

        let reader = BlcoStoreReader::open(&p).unwrap();
        let bad = reader.num_blocks() - 1;
        match reader.load_block(bad) {
            Err(StoreError::ChecksumMismatch { what, .. }) => {
                assert!(what.contains("block"), "{what}");
            }
            other => panic!("{codec:?}: expected ChecksumMismatch, got {other:?}"),
        }
        assert!(
            reader.verify_payloads().is_err(),
            "{codec:?}: verify must reject the flipped payload bit"
        );
        std::fs::remove_file(&p).ok();
    }
}
