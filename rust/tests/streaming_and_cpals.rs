//! Integration over the coordinator: out-of-memory streaming equivalence,
//! facade routing, and CP-ALS convergence through every path.

use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::cpals::CpAlsOptions;
use blco::device::{Counters, Profile};
use blco::format::blco::BlcoConfig;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::tensor::synth;

#[test]
fn streamed_and_in_memory_paths_agree_bitwise_modulo_fp() {
    let t = synth::fiber_clustered(&[80, 60, 40], 12_000, 2, 0.9, 17);
    let cfg = BlcoConfig { max_block_nnz: 1024, ..Default::default() };
    let factors = random_factors(&t.dims, 16, 23);

    let big = MttkrpEngine::from_coo_with(&t, Profile::a100(), cfg).with_threads(4);
    let small = MttkrpEngine::from_coo_with(&t, Profile::tiny(64 * 1024), cfg)
        .with_threads(4);
    assert!(!big.is_oom(16));
    assert!(small.is_oom(16));

    for target in 0..3 {
        let (m_in, p_in) = big.mttkrp(target, &factors);
        let (m_st, p_st) = small.mttkrp(target, &factors);
        assert!(matches!(p_in, ExecPath::InMemory(_)));
        assert!(matches!(p_st, ExecPath::Streamed(_)));
        let expect = mttkrp_oracle(&t, target, &factors);
        assert!(m_in.max_abs_diff(&expect) < 1e-8, "in-memory mode {target}");
        assert!(m_st.max_abs_diff(&expect) < 1e-8, "streamed mode {target}");
    }
}

#[test]
fn cpals_converges_on_streamed_path() {
    // even when every MTTKRP is streamed through a tiny device, CP-ALS
    // must converge identically in structure
    let t = synth::fiber_clustered(&[40, 30, 20], 5_000, 2, 1.0, 31);
    let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
    let engine = MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg)
        .with_threads(4);
    assert!(engine.is_oom(8));
    let rep = engine.cp_als(CpAlsOptions {
        rank: 8,
        max_iters: 8,
        tol: 0.0,
        threads: 4,
        seed: 2,
    });
    assert_eq!(rep.fits.len(), 8);
    // monotone-ish improvement over the run as a whole
    assert!(
        rep.fits.last().unwrap() >= &(rep.fits[0] - 1e-6),
        "fits {:?}",
        rep.fits
    );
}

#[test]
fn oom_preset_streams_on_every_real_profile() {
    // a downsized Amazon-like tensor (the real preset is exercised by the
    // fig10 bench; this keeps the test suite fast)
    let t = synth::fiber_clustered(&[12_000, 4_500, 4_500], 300_000, 2, 0.6, 7);
    for prof in Profile::all() {
        let mut small = prof.clone();
        small.dev_mem_bytes = 1 << 20; // scale the budget to the scaled tensor
        let engine = MttkrpEngine::from_coo_with(
            &t,
            small,
            BlcoConfig { max_block_nnz: 1 << 15, ..Default::default() },
        )
        .with_threads(8);
        assert!(engine.is_oom(32), "{}", prof.name);
        let factors = random_factors(&t.dims, 32, 5);
        let (m, path) = engine.mttkrp(0, &factors);
        let ExecPath::Streamed(rep) = path else {
            panic!("expected streaming on {}", prof.name)
        };
        assert!(rep.batches.len() > 1);
        // perfect overlap invariant: overall ≥ serialized link time
        assert!(rep.overall_s >= rep.transfer_s * 0.999);
        let expect = mttkrp_oracle(&t, 0, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-8);
    }
}

#[test]
fn counters_volume_scales_with_rank() {
    let t = synth::uniform(&[50, 50, 50], 5_000, 3);
    let engine = MttkrpEngine::from_coo(&t, Profile::a100());
    let f8 = random_factors(&t.dims, 8, 1);
    let f32f = random_factors(&t.dims, 32, 1);
    engine.counters.reset();
    let _ = engine.mttkrp(0, &f8);
    let v8 = engine.counters.snapshot().volume_bytes();
    engine.counters.reset();
    let _ = engine.mttkrp(0, &f32f);
    let v32 = engine.counters.snapshot().volume_bytes();
    // gather traffic scales with rank (sublinearly: cache-resident repeats
    // are excluded from global volume)
    assert!(v32 > v8 * 2, "v8 {v8} v32 {v32}");
    let _ = Counters::new();
}
