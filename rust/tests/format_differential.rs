//! Cross-format differential property suite: every format engine — BLCO
//! (register and hierarchical resolution, with and without blocking keys),
//! CSF, B-CSF, MM-CSF, HiCOO, F-COO, COO-atomic, GenTen — computes the
//! same MTTKRP as a *naive dense reference* (explicit matricization ×
//! Khatri-Rao product over a dense copy of the tensor), for **every** mode,
//! over seeded random tensors of orders 3–5 with skewed dims, empty
//! slices, and single-non-zero edge cases. This pins all formats to one
//! oracle that is independent of the COO-walk serial oracle the unit tests
//! use (cf. the MM-CSF cross-comparisons in Nisa et al.).

use blco::device::{Counters, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::fcoo::FCoo;
use blco::format::hicoo::HicooTensor;
use blco::mttkrp::blco::{BlcoEngine, Resolution};
use blco::mttkrp::coo::CooAtomicEngine;
use blco::mttkrp::csf::{BCsfEngine, CsfEngine, MmCsfEngine};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::fcoo::FCooEngine;
use blco::mttkrp::genten::GenTenEngine;
use blco::mttkrp::hicoo::HicooEngine;
use blco::mttkrp::oracle::random_factors;
use blco::mttkrp::Mttkrp;
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;
use blco::util::prng::Rng;

const TOL: f64 = 1e-9;

/// Naive dense reference: materialize the tensor densely, then accumulate
/// `out[c_target] += X[c] * prod_{n != target} factors[n][c_n]` cell by
/// cell. Independent of every sparse walk in the crate.
fn dense_reference(t: &CooTensor, target: usize, factors: &[Matrix]) -> Matrix {
    let dims: Vec<usize> = t.dims.iter().map(|&d| d as usize).collect();
    let cells: usize = dims.iter().product();
    assert!(cells <= 1 << 21, "dense reference needs a small tensor ({cells} cells)");
    let mut dense = vec![0.0f64; cells];
    for e in 0..t.nnz() {
        let mut idx = 0usize;
        for (n, &d) in dims.iter().enumerate() {
            idx = idx * d + t.coords[n][e] as usize;
        }
        dense[idx] += t.vals[e];
    }
    let rank = factors[0].cols;
    let mut out = Matrix::zeros(dims[target], rank);
    let mut coord = vec![0usize; dims.len()];
    for (idx, &v) in dense.iter().enumerate() {
        if v == 0.0 {
            continue;
        }
        let mut rem = idx;
        for n in (0..dims.len()).rev() {
            coord[n] = rem % dims[n];
            rem /= dims[n];
        }
        let o = out.row_mut(coord[target]);
        for k in 0..rank {
            let mut p = v;
            for (n, f) in factors.iter().enumerate() {
                if n != target {
                    p *= f.row(coord[n])[k];
                }
            }
            o[k] += p;
        }
    }
    out
}

/// Every engine under test over one tensor. BLCO appears four ways: both
/// conflict resolutions, plus a register-path build with a lowered
/// in-block bit budget so real blocking keys (non-zero per-mode bases)
/// are exercised even on small shapes.
fn engines(t: &CooTensor) -> Vec<Box<dyn Mttkrp>> {
    let keyed = BlcoConfig { inblock_budget: 9, ..Default::default() };
    vec![
        Box::new(CooAtomicEngine::new(t.clone())),
        Box::new(GenTenEngine::new(t.clone())),
        Box::new(HicooEngine::new(HicooTensor::from_coo(t, 4))),
        Box::new(FCooEngine::new(FCoo::from_coo(t, 64))),
        Box::new(CsfEngine::new(t)),
        Box::new(BCsfEngine::new(t, 128)),
        Box::new(MmCsfEngine::new(t)),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo(t), Profile::a100())
                .with_resolution(Resolution::Register),
        ),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo(t), Profile::a100())
                .with_resolution(Resolution::Hierarchical),
        ),
        Box::new(
            BlcoEngine::new(BlcoTensor::from_coo_with(t, keyed), Profile::intel_d1())
                .with_resolution(Resolution::Register),
        ),
    ]
}

fn differential_check(t: &CooTensor, rank: usize, label: &str) {
    let factors = random_factors(&t.dims, rank, 0xD1FF ^ rank as u64);
    for target in 0..t.order() {
        let expect = dense_reference(t, target, &factors);
        for eng in engines(t) {
            let mut out = Matrix::zeros(t.dims[target] as usize, rank);
            eng.mttkrp(target, &factors, &mut out, 4, &Counters::new());
            let d = out.max_abs_diff(&expect);
            assert!(
                d < TOL,
                "{label}: {} mode {target} diverges from the dense reference \
                 by {d:e} (dims {:?}, nnz {}, rank {rank})",
                eng.name(),
                t.dims,
                t.nnz()
            );
        }
    }
}

/// Random tensor with skewed dims: one long mode, the rest short, so the
/// dense cell count stays bounded while mode lengths differ by ~30x.
fn skewed_tensor(rng: &mut Rng, order: usize) -> CooTensor {
    let long_mode = rng.below(order as u64) as usize;
    let dims: Vec<u64> = (0..order)
        .map(|n| if n == long_mode { 30 + rng.below(90) } else { 2 + rng.below(6) })
        .collect();
    let cells: u64 = dims.iter().product();
    let nnz = 1 + rng.below((cells / 2).clamp(1, 2_000)) as usize;
    synth::uniform(&dims, nnz, rng.next_u64())
}

#[test]
fn seeded_random_orders_3_to_5() {
    let mut rng = Rng::new(0xF0_4A7);
    let ranks = [1usize, 5, 16];
    for case in 0..12 {
        let order = 3 + case % 3;
        let t = skewed_tensor(&mut rng, order);
        differential_check(&t, ranks[case % ranks.len()], &format!("case {case}"));
    }
}

#[test]
fn empty_slices_stay_zero() {
    // every mode has empty prefix and suffix slices: non-zeros only use
    // the interior index range, so each engine must leave those output
    // rows exactly zero and still match the dense reference
    let dims = [12u64, 9, 7, 5];
    let mut t = CooTensor::new(&dims);
    let mut rng = Rng::new(42);
    for _ in 0..120 {
        let c: Vec<u32> = dims.iter().map(|&d| 2 + rng.below(d - 4) as u32).collect();
        t.push(&c, rng.normal());
    }
    t.sum_duplicates();
    differential_check(&t, 8, "empty-slices");
    // spot-check the guarantee on one engine output
    let factors = random_factors(&t.dims, 8, 7);
    let eng = CsfEngine::new(&t);
    for target in 0..t.order() {
        let mut out = Matrix::zeros(dims[target] as usize, 8);
        eng.mttkrp(target, &factors, &mut out, 2, &Counters::new());
        for empty_row in [0usize, 1, dims[target] as usize - 1] {
            assert!(
                out.row(empty_row).iter().all(|&x| x == 0.0),
                "mode {target} empty slice {empty_row} picked up mass"
            );
        }
    }
}

#[test]
fn single_nonzero_every_order() {
    for order in 3..=5usize {
        let dims: Vec<u64> = (0..order).map(|n| 3 + n as u64).collect();
        // corner non-zero
        let mut corner = CooTensor::new(&dims);
        corner.push(&vec![0u32; order], 2.5);
        differential_check(&corner, 4, &format!("corner order {order}"));
        // interior non-zero at the highest coordinate
        let mut last = CooTensor::new(&dims);
        let c: Vec<u32> = dims.iter().map(|&d| (d - 1) as u32).collect();
        last.push(&c, -1.5);
        differential_check(&last, 3, &format!("last-cell order {order}"));
    }
}

#[test]
fn order5_skewed_and_hypersparse() {
    // DARPA-like: nnz ~ distinct fibers over a long order-5 shape
    let t = synth::uniform(&[64, 3, 2, 5, 4], 500, 99);
    differential_check(&t, 16, "order-5 skewed");
    let flat = synth::fiber_clustered(&[40, 11, 6], 700, 0, 0.0, 17);
    differential_check(&flat, 8, "hypersparse fibers");
}

#[test]
fn max_rank_boundary_against_dense() {
    let t = synth::uniform(&[14, 11, 9], 400, 21);
    differential_check(&t, 64, "max rank");
}
