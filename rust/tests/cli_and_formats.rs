//! Integration over the user-facing surfaces: .tns round-trip through the
//! whole pipeline, preset coverage of every format, and TTV on the
//! streaming engine's tensor — the paths the CLI drives.

use blco::coordinator::engine::MttkrpEngine;
use blco::device::{Counters, Profile};
use blco::format::blco::BlcoTensor;
use blco::format::csf::Csf;
use blco::format::fcoo::FCoo;
use blco::format::hicoo::HicooTensor;
use blco::format::mmcsf::MmCsf;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::ops::ttv::ttv;
use blco::tensor::{datasets, io, synth};

#[test]
fn tns_file_through_full_pipeline() {
    // write a .tns, read it back, run MTTKRP through the facade
    let t = synth::fiber_clustered(&[80, 60, 40], 4_000, 2, 0.9, 5);
    let mut path = std::env::temp_dir();
    path.push(format!("blco_it_{}.tns", std::process::id()));
    io::write_tns(&path, &t).unwrap();
    let back = io::read_tns(&path, Some(&t.dims)).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.nnz(), t.nnz());

    let engine = MttkrpEngine::from_coo(&back, Profile::a100()).with_threads(2);
    let factors = random_factors(&back.dims, 8, 1);
    let (m, _) = engine.mttkrp(0, &factors);
    let expect = mttkrp_oracle(&t, 0, &factors);
    assert!(m.max_abs_diff(&expect) < 1e-8);
}

#[test]
fn every_format_constructs_on_small_presets() {
    // the format zoo must digest representative skewed/hypersparse shapes
    for name in ["uber", "darpa", "nips"] {
        let mut preset = datasets::by_name(name).unwrap();
        preset.nnz = preset.nnz.min(30_000); // keep the suite fast
        let t = preset.build();
        let b = BlcoTensor::from_coo_with(&t, preset.blco_config());
        assert_eq!(b.nnz, t.nnz(), "{name} blco");
        let f = FCoo::from_coo(&t, 256);
        assert_eq!(f.modes.len(), t.order(), "{name} fcoo");
        let c = Csf::from_coo(&t, &(0..t.order()).collect::<Vec<_>>());
        assert_eq!(c.nnz(), t.nnz(), "{name} csf");
        let m = MmCsf::from_coo(&t);
        assert_eq!(
            m.groups.iter().map(|g| g.csf.nnz()).sum::<usize>(),
            t.nnz(),
            "{name} mmcsf"
        );
        let h = HicooTensor::from_coo(&t, 7);
        assert_eq!(h.nnz, t.nnz(), "{name} hicoo");
    }
}

#[test]
fn ttv_consistent_with_mttkrp_rank_one() {
    // rank-1 MTTKRP with all-ones non-target factors except mode c reduces
    // to a TTV against that factor column summed over remaining modes —
    // cross-validate the two kernels on a 3-mode tensor:
    //   M[i, 0] = Σ_{j,k} x_{ijk} * a_j * b_k
    //   ttv(ttv(X, 2, b), 1, a)[i] must equal it
    let dims = [30u64, 20, 10];
    let t = synth::uniform(&dims, 1_500, 9);
    let b = BlcoTensor::from_coo(&t);
    let mut rng = blco::util::prng::Rng::new(3);
    let va: Vec<f64> = (0..dims[1]).map(|_| rng.normal()).collect();
    let vb: Vec<f64> = (0..dims[2]).map(|_| rng.normal()).collect();

    // MTTKRP path (rank 1)
    let factors = vec![
        blco::mttkrp::dense::Matrix::zeros(30, 1), // target, unused
        blco::mttkrp::dense::Matrix { rows: 20, cols: 1, data: va.clone() },
        blco::mttkrp::dense::Matrix { rows: 10, cols: 1, data: vb.clone() },
    ];
    let eng = blco::mttkrp::blco::BlcoEngine::new(b.clone(), Profile::a100());
    let mut m = blco::mttkrp::dense::Matrix::zeros(30, 1);
    blco::mttkrp::Mttkrp::mttkrp(&eng, 0, &factors, &mut m, 2, &Counters::new());

    // double-TTV path
    let y = ttv(&b, 2, &vb, 2); // (30, 20)
    let yb = BlcoTensor::from_coo(&y);
    let z = ttv(&yb, 1, &va, 2); // (30,)
    let mut dense = vec![0.0f64; 30];
    for e in 0..z.nnz() {
        dense[z.coords[0][e] as usize] += z.vals[e];
    }
    for i in 0..30 {
        assert!(
            (dense[i] - m.row(i)[0]).abs() < 1e-9,
            "row {i}: ttv {} vs mttkrp {}",
            dense[i],
            m.row(i)[0]
        );
    }
}

#[test]
fn demo_presets_cover_runtime_artifacts() {
    // keep the promise the PJRT path depends on: demo tensors fit the AOT
    // variant dims even after regeneration
    for p in [datasets::demo3(), datasets::demo4()] {
        let t = p.build();
        t.validate().unwrap();
        for (n, &d) in t.dims.iter().enumerate() {
            let max = t.coords[n].iter().copied().max().unwrap_or(0) as u64;
            assert!(max < d, "{}: mode {n}", p.name);
        }
    }
}
