//! Offline shim of the [`anyhow`](https://docs.rs/anyhow) API — exactly the
//! subset this repository uses: [`Error`], [`Result`], the [`Context`]
//! extension trait on `Result`/`Option`, and the [`anyhow!`]/[`bail!`]
//! macros. Error chains render like the real crate's: `Display` shows the
//! outermost message, `{:#}` and `Debug` walk the "Caused by" chain.

use std::fmt;

/// A type-erased error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error under a new outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            if causes.len() == 1 {
                write!(f, "\n    {}", causes[0])?;
            } else {
                for (i, c) in causes.iter().enumerate() {
                    write!(f, "\n    {i}: {c}")?;
                }
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error` (this is why `Error` itself must
// never implement `std::error::Error` — coherence with the impl below).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // preserve the source chain as message text
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            msgs.push(err.to_string());
            cur = err.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut out = Error::msg(it.next().unwrap_or_default());
        for m in it {
            out = out.context(m);
        }
        out
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// `.context(..)` on a Result that is already anyhow-typed (no overlap with
// the impl above: `Error` does not implement `std::error::Error`).
impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_renders() {
        let r: Result<()> = Err(io_err()).context("opening file");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        let full = format!("{e:#}");
        assert!(full.starts_with("opening file: "), "{full}");
        assert!(full.contains("missing"), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(format!("{}", r.unwrap_err()), "missing 7");
        let ok: Result<i32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn anyhow_result_context() {
        fn inner() -> Result<()> {
            bail!("inner {}", 42)
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(e.root_cause(), "inner 42");
    }

    #[test]
    fn question_mark_converts() {
        fn run() -> Result<()> {
            let _: usize = "abc".parse()?;
            Ok(())
        }
        assert!(run().is_err());
    }
}
