//! Offline **stub** of the `xla`/PJRT bridge used by `blco::runtime`.
//!
//! The offline build environment has no XLA/PJRT shared library, so this
//! crate supplies the exact API surface the runtime layer compiles against
//! (`PjRtClient`, `HloModuleProto`, `XlaComputation`, `Literal`,
//! `PjRtLoadedExecutable`) with every fallible operation reporting
//! [`Error::Unavailable`]. The PJRT integration tests already skip
//! themselves when no artifacts manifest exists, so the stub keeps the
//! whole workspace building and testing without PJRT. Replacing this path
//! dependency with a real `xla-rs` build re-enables AOT execution without
//! touching `blco` sources.

use std::fmt;
use std::path::Path;

/// Stub error: the PJRT bridge is unavailable in offline builds.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT unavailable in this offline build (stub `xla` crate): {what}"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types the [`Literal`] constructors accept.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// A host-side literal (stub: shape/bytes are not retained).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("PJRT unavailable"), "{msg}");
    }
}
