//! Format explorer: build every sparse format the paper discusses on the
//! same tensor and compare footprints, construction cost and MTTKRP
//! traffic — a hands-on version of Sections 3–4.
//!
//!     cargo run --release --example format_explorer [preset]

use blco::bench::Table;
use blco::device::{Counters, Profile};
use blco::format::blco::BlcoTensor;
use blco::format::csf::Csf;
use blco::format::fcoo::FCoo;
use blco::format::mmcsf::MmCsf;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::coo::CooAtomicEngine;
use blco::mttkrp::csf::{mode_order_with_root, MmCsfEngine};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::fcoo::FCooEngine;
use blco::mttkrp::oracle::random_factors;
use blco::mttkrp::Mttkrp;
use blco::tensor::{datasets, stats};
use blco::util::timer::fmt_duration;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "nell2".into());
    let preset = datasets::by_name(&name).expect("unknown preset");
    println!("building {name} ...");
    let t = preset.build();
    println!("dims {:?}, nnz {}, density {:.2e}\n", t.dims, t.nnz(), t.density());

    for m in 0..t.order() {
        let fs = stats::fiber_stats(&t, m);
        println!(
            "mode-{m} fibers: {} (avg {:.2} nnz, max {}), slice imbalance {:.1}",
            fs.fibers,
            fs.avg_len,
            fs.max_len,
            stats::imbalance(&stats::slice_histogram(&t, m)),
        );
    }
    println!();

    // ---- construction cost + footprint
    let tbl = Table::new(&[10, 14, 14, 24]);
    tbl.header(&["format", "build", "bytes/nnz", "note"]);

    let w0 = std::time::Instant::now();
    let blco = BlcoTensor::from_coo_with(&t, preset.blco_config());
    let blco_build = w0.elapsed();
    tbl.row(&[
        "BLCO".into(),
        fmt_duration(blco_build),
        format!("{:.1}", blco.footprint_bytes() as f64 / t.nnz() as f64),
        format!("{} blocks", blco.blocks.len()),
    ]);

    let w0 = std::time::Instant::now();
    let fcoo = FCoo::from_coo(&t, 256);
    tbl.row(&[
        "F-COO".into(),
        fmt_duration(w0.elapsed()),
        format!("{:.1}", fcoo.footprint_bytes() as f64 / t.nnz() as f64),
        format!("{} mode copies", t.order()),
    ]);

    let w0 = std::time::Instant::now();
    let csf: Vec<Csf> = (0..t.order())
        .map(|m| Csf::from_coo(&t, &mode_order_with_root(t.order(), m)))
        .collect();
    tbl.row(&[
        "CSF-N".into(),
        fmt_duration(w0.elapsed()),
        format!(
            "{:.1}",
            csf.iter().map(|c| c.footprint_bytes()).sum::<usize>() as f64
                / t.nnz() as f64
        ),
        format!("{} trees", t.order()),
    ]);

    let w0 = std::time::Instant::now();
    let mm = MmCsf::from_coo(&t);
    tbl.row(&[
        "MM-CSF".into(),
        fmt_duration(w0.elapsed()),
        format!("{:.1}", mm.footprint_bytes() as f64 / t.nnz() as f64),
        format!("{} orientation groups", mm.groups.len()),
    ]);
    tbl.row(&[
        "COO".into(),
        "-".into(),
        format!("{:.1}", t.footprint_bytes() as f64 / t.nnz() as f64),
        "raw".into(),
    ]);

    // ---- traffic comparison on mode 0
    println!("\nmode-0 MTTKRP traffic (rank 32):");
    let factors = random_factors(&t.dims, 32, 3);
    let engines: Vec<Box<dyn Mttkrp>> = vec![
        Box::new(BlcoEngine::new(blco, Profile::a100())),
        Box::new(MmCsfEngine { mm }),
        Box::new(FCooEngine::new(fcoo)),
        Box::new(CooAtomicEngine::new(t.clone())),
    ];
    let tbl = Table::new(&[12, 12, 12, 12, 12]);
    tbl.header(&["engine", "volume(GB)", "coalesced", "atomics", "segments"]);
    for eng in engines {
        let c = Counters::new();
        let mut out = Matrix::zeros(t.dims[0] as usize, 32);
        eng.mttkrp(0, &factors, &mut out, 8, &c);
        let s = c.snapshot();
        tbl.row(&[
            eng.name(),
            format!("{:.3}", s.volume_bytes() as f64 / 1e9),
            format!("{:.2}", s.coalesced_frac()),
            s.atomics.to_string(),
            s.segments.to_string(),
        ]);
    }
}
