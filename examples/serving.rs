//! Minimal serving-layer walkthrough: register two tensors behind one
//! scaled device, watch the admission controller route (and reject)
//! requests, then replay a tiny two-tenant burst through the
//! [`ServeRequest`] builder and compare the fair, fused policy against
//! the one-job-at-a-time baseline — plus an EDF run with deadlines and
//! load shedding, the production-serving knobs.
//!
//!     cargo run --release --example serving

use blco::device::Profile;
use blco::format::blco::BlcoConfig;
use blco::mttkrp::MAX_RANK;
use blco::service::{
    admit_mttkrp, JobKind, JobRequest, SchedPolicy, ServeRequest, ShedPolicy, Tenant,
    TensorRegistry,
};
use blco::tensor::synth;
use blco::util::pool::default_threads;

fn main() {
    let threads = default_threads();
    // 48 KiB of simulated device memory: "hot" fits, "cold" must stream
    let mut reg = TensorRegistry::new(Profile::tiny(48 * 1024));
    reg.register("hot", &synth::uniform(&[40, 30, 20], 1_000, 1), BlcoConfig::default());
    reg.register(
        "cold",
        &synth::uniform(&[60, 50, 40], 8_000, 2),
        BlcoConfig { max_block_nnz: 512, ..Default::default() },
    );

    println!("admission decisions (rank 8):");
    for name in reg.names() {
        let eng = &reg.get(&name).unwrap().engine;
        for mode in 0..eng.dims.len() {
            match admit_mttkrp(eng, mode, 8) {
                Ok(a) => println!(
                    "  {name} mode {mode}: {:?} (working set {} B, floor {} B)",
                    a.route, a.working_set_bytes, a.floor_bytes
                ),
                Err(e) => println!("  {name} mode {mode}: rejected — {e}"),
            }
        }
    }
    // an unservable request is an error value, not a panic
    let oversized = admit_mttkrp(&reg.get("cold").unwrap().engine, 0, MAX_RANK + 1);
    println!("  cold at rank {}: {}", MAX_RANK + 1, oversized.unwrap_err());

    // two tenants, a burst of same-(tensor, mode, rank) streamed jobs plus
    // an in-memory job: the fused policy ships the cold payload once
    let tenants = vec![
        Tenant { name: "acme".into(), weight: 2 },
        Tenant { name: "labs".into(), weight: 1 },
    ];
    let job = |id: usize, tenant: &str, tensor: &str, target: usize| {
        JobRequest::new(
            id,
            tenant,
            tensor,
            JobKind::Mttkrp { target, rank: 8, seed: 0xBEEF + id as u64 },
            0.0,
        )
    };
    let jobs = vec![
        job(0, "acme", "cold", 0),
        job(1, "labs", "cold", 0),
        job(2, "acme", "cold", 0),
        job(3, "labs", "hot", 1),
        job(4, "acme", "cold", 0),
    ];

    let fused = ServeRequest::new(&reg)
        .trace(&tenants, &jobs)
        .threads(threads)
        .run()
        .expect("valid request")
        .into_report();
    // fresh registry (same payload Arcs) for an untouched schedule cache
    let mut reg2 = TensorRegistry::new(Profile::tiny(48 * 1024));
    for name in reg.names() {
        reg2.register_shared(&name, reg.get(&name).unwrap().engine.tensor());
    }
    let naive = ServeRequest::new(&reg2)
        .trace(&tenants, &jobs)
        .policy(SchedPolicy::Fifo)
        .batching(false)
        .threads(threads)
        .run()
        .expect("valid request")
        .into_report();

    println!("\nfused policy : makespan {:.3} ms, {} fused group(s), {:.1} KiB shipped",
        fused.makespan_s * 1e3, fused.fused_groups, fused.bytes_shipped as f64 / 1024.0);
    println!(
        "naive policy : makespan {:.3} ms, {} fused group(s), {:.1} KiB shipped",
        naive.makespan_s * 1e3,
        naive.fused_groups,
        naive.bytes_shipped as f64 / 1024.0
    );
    assert!(fused.fused_groups >= 1, "the t=0 burst must fuse");
    assert!(
        fused.makespan_s < naive.makespan_s,
        "one shipped pass must beat four"
    );

    // production knobs: tight deadlines + EDF + shedding. The tight job
    // jumps the queue under EDF; at overload a late streamed job degrades
    // to a coarser rank (shed) instead of missing or being rejected.
    let service_s = fused
        .outcomes
        .iter()
        .find(|o| o.tenant == "acme")
        .map(|o| o.duration_s)
        .unwrap_or(1e-3);
    let slo_jobs: Vec<JobRequest> = (0..4)
        .map(|i| {
            job(i, if i % 2 == 0 { "acme" } else { "labs" }, "cold", 0)
                .with_deadline(if i == 3 { 1.5 * service_s } else { 50.0 * service_s })
        })
        .collect();
    let edf = ServeRequest::new(&reg)
        .trace(&tenants, &slo_jobs)
        .policy(SchedPolicy::Edf)
        .batching(false)
        .threads(threads)
        .shed(ShedPolicy::default())
        .run()
        .expect("valid request")
        .into_report();
    println!(
        "\nEDF with SLOs: p99 {:.3} ms, {}/{} deadline misses, {} shed",
        edf.latency.p99 * 1e3,
        edf.deadline_misses,
        edf.deadline_jobs,
        edf.shed_jobs
    );

    println!(
        "\nsame-(tensor, mode, rank) requests rode one streamed pass over the \
         single resident tensor copy — the paper's unified-format property \
         doing serving work"
    );
}
