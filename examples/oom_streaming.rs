//! Out-of-memory streaming (Figure 10's mechanism, scaled down): run a
//! tensor whose working set exceeds the simulated device memory, watch the
//! coordinator pipeline batches through the device queues, and report
//! overall vs in-memory throughput.
//!
//!     cargo run --release --example oom_streaming [preset]
//!
//! Defaults to a fast down-scaled Amazon-like tensor; pass `amazon`,
//! `patents` or `reddit` for the full Figure-10 presets (slower to build).

use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::cpals::CpAlsOptions;
use blco::device::model::throughput_tbps;
use blco::device::{LinkTopology, Profile};
use blco::format::blco::BlcoConfig;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::{coo::CooTensor, datasets, synth};
use blco::util::pool::default_threads;
use blco::{StreamOutcome, StreamRequest};

fn build(name: &str) -> (String, CooTensor, BlcoConfig, Profile) {
    if let Some(p) = datasets::by_name(name) {
        if p.oom {
            return (name.to_string(), p.build(), p.blco_config(), Profile::a100());
        }
    }
    // fast default: Amazon shrunk 10x, device memory shrunk to match
    let t = synth::fiber_clustered(&[12_000, 4_500, 4_500], 1_200_000, 2, 0.6, 7);
    let mut prof = Profile::a100();
    prof.dev_mem_bytes /= 10;
    let cfg = BlcoConfig { max_block_nnz: 1 << 16, ..Default::default() };
    ("amazon/10 (default)".into(), t, cfg, prof)
}

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "fast".into());
    let (label, t, cfg, profile) = build(&name);
    println!("tensor {label}: dims {:?}, nnz {}", t.dims, t.nnz());

    let rank = 32;
    let threads = default_threads();
    let engine = MttkrpEngine::from_coo_with(&t, profile, cfg).with_threads(threads);
    let ws = engine.working_set_bytes(rank);
    println!(
        "working set {:.1} MiB vs device memory {:.1} MiB → {}",
        ws as f64 / (1 << 20) as f64,
        engine.eng.profile.dev_mem_bytes as f64 / (1 << 20) as f64,
        if engine.is_oom(rank) { "OUT-OF-MEMORY (streaming)" } else { "in-memory" },
    );
    assert!(engine.is_oom(rank), "pick an OOM preset");

    let factors = random_factors(&t.dims, rank, 11);
    println!(
        "\nstreaming through {} device queue(s), {} batches:",
        engine.eng.profile.queues,
        engine.eng.num_batches()
    );
    for mode in 0..t.order() {
        engine.counters.reset();
        let mut out = Matrix::zeros(t.dims[mode] as usize, rank);
        let rep = StreamRequest::new(&engine.eng, mode)
            .job(&factors)
            .devices(1)
            .threads(threads)
            .counters(&engine.counters)
            .run(std::slice::from_mut(&mut out))
            .expect("valid stream request")
            .into_streamed()
            .expect("one device streams");
        let vol = engine.counters.snapshot().volume_bytes();
        println!(
            "mode {mode}: {:>5.1} MiB shipped | overall {:.2} TB/s, in-memory {:.2} TB/s \
             | link busy {:.0}% of {:.1} ms end-to-end (wall {:.0} ms)",
            rep.bytes as f64 / (1 << 20) as f64,
            throughput_tbps(vol, rep.overall_s),
            throughput_tbps(vol, rep.compute_s.max(1e-12)),
            rep.overlap_efficiency() * 100.0,
            rep.overall_s * 1e3,
            rep.wall_s * 1e3,
        );
    }
    println!(
        "\nthe gap between overall and in-memory throughput is the \
         host-device interconnect — the paper's Figure 10 conclusion"
    );

    // ---- past the paper: shard the same streamed MTTKRP across a
    // simulated multi-GPU cluster (greedy load-balanced batch placement,
    // tree-merged partials) and watch mode-0 throughput scale 1 → 4
    // devices under both host-link topologies.
    println!("\nmulti-device scaling (mode 0):");
    for links in [LinkTopology::Shared, LinkTopology::Dedicated] {
        let mut base = 0.0f64;
        for d in [1usize, 2, 4] {
            let prof = engine.eng.profile.clone().with_devices(d).with_links(links);
            // share the BLCO tensor through its Arc — no payload copy
            let eng = engine.eng.share_with_profile(prof.clone());
            let counters = blco::device::Counters::new();
            let mut out = Matrix::zeros(t.dims[0] as usize, rank);
            // one request either way: d = 1 routes to the single-device
            // pipeline, d > 1 to the sharded cluster path
            let outcome = StreamRequest::new(&eng, 0)
                .job(&factors)
                .threads(threads)
                .counters(&counters)
                .run(std::slice::from_mut(&mut out))
                .expect("valid request");
            let vol = counters.snapshot().volume_bytes();
            let (overall, stream_s, merge_s, imbalance, occupancy) = match &outcome {
                StreamOutcome::Streamed(r) => {
                    (r.overall_s, r.overall_s, 0.0, 1.0, r.overlap_efficiency())
                }
                StreamOutcome::Clustered(r) => (
                    r.overall_s,
                    r.stream_s,
                    r.merge_s,
                    r.imbalance(),
                    r.link_occupancy(&prof),
                ),
            };
            if d == 1 {
                base = overall;
            }
            println!(
                "  {:>9} links, {d} device(s): overall {:.2} TB/s \
                 ({:.2}x vs 1 dev) | stream {:.1} ms + merge {:.1} ms | \
                 imbalance {:.3} | link busy {:.0}%",
                format!("{links:?}").to_lowercase(),
                throughput_tbps(vol, overall),
                base / overall.max(1e-12),
                stream_s * 1e3,
                merge_s * 1e3,
                imbalance,
                occupancy * 100.0,
            );
        }
    }
    println!(
        "\nshared links saturate the single host interconnect; dedicated \
         links recover near-linear streaming scaling with the tree merge \
         as the remaining fixed cost"
    );

    // ---- decomposition scale: CP-ALS through the facade plans each
    // mode's streaming schedule once and reuses it every iteration
    // (mode-aware routing would also let short modes run in-memory here,
    // but this tensor is OOM in every mode).
    let opts = CpAlsOptions { rank: 16, max_iters: 3, tol: 0.0, threads, seed: 5 };
    let rep = engine.cp_als(opts);
    println!("\nCP-ALS (rank {}, {} iterations) through the facade:", opts.rank, rep.iterations);
    println!(
        "  plans built {} (one per streamed mode), reused {}x",
        rep.schedule.built, rep.schedule.hits
    );
    for (n, tr) in rep.mode_traces.iter().enumerate() {
        let last = tr.last.as_ref().map(ExecPath::summary).unwrap_or_else(|| "-".into());
        println!(
            "  mode {n}: in-memory {} | streamed {} | clustered {} | {last}",
            tr.in_memory, tr.streamed, tr.clustered
        );
    }
    println!(
        "  OOM traffic {:.1} MiB, final fit {:.4}",
        rep.stream.bytes as f64 / (1 << 20) as f64,
        rep.fits.last().copied().unwrap_or(0.0)
    );
    assert_eq!(
        rep.schedule.built,
        t.order(),
        "schedule cache must plan once per (mode, rank), not per iteration"
    );
}
