//! CP-ALS demo: decompose a synthetic tensor with *planted* low-rank
//! structure and watch the fit recover it (Algorithm 1 end to end, with
//! the BLCO unified MTTKRP doing the heavy lifting).
//!
//! Note the construction: each rank-1 component's factor vectors are
//! supported on a small random subset of each mode, so the component is a
//! dense block and the full tensor (zeros included) is *exactly* rank ≤ R —
//! sampling random entries of a dense low-rank model would NOT give a
//! low-rank sparse tensor (the implicit zeros break the structure).
//!
//!     cargo run --release --example cpals_demo

use blco::coordinator::engine::MttkrpEngine;
use blco::cpals::CpAlsOptions;
use blco::device::Profile;
use blco::tensor::coo::CooTensor;
use blco::util::prng::Rng;

/// A tensor that is exactly the sum of `rank` block-supported rank-1
/// components (plus small noise on the support).
fn planted_block_low_rank(
    dims: &[u64],
    rank: usize,
    support: usize,
    noise: f64,
    seed: u64,
) -> CooTensor {
    let mut rng = Rng::new(seed);
    let order = dims.len();
    // per component and mode: a sparse factor vector (support rows)
    let mut supports: Vec<Vec<Vec<(u32, f64)>>> = Vec::new(); // [k][n] -> rows
    for _k in 0..rank {
        let mut per_mode = Vec::new();
        for &d in dims {
            let mut rows: Vec<u32> = (0..support)
                .map(|_| rng.below(d) as u32)
                .collect();
            rows.sort_unstable();
            rows.dedup();
            per_mode.push(
                rows.into_iter()
                    .map(|r| (r, 0.5 + rng.f64()))
                    .collect::<Vec<_>>(),
            );
        }
        supports.push(per_mode);
    }
    let mut t = CooTensor::new(dims);
    // enumerate each component's block
    for k in 0..rank {
        let mut idx = vec![0usize; order];
        'outer: loop {
            let mut coord = vec![0u32; order];
            let mut v = 1.0;
            for n in 0..order {
                let (r, val) = supports[k][n][idx[n]];
                coord[n] = r;
                v *= val;
            }
            t.push(&coord, v + noise * rng.normal());
            // odometer over the support sets
            let mut n = order;
            loop {
                if n == 0 {
                    break 'outer;
                }
                n -= 1;
                idx[n] += 1;
                if idx[n] < supports[k][n].len() {
                    break;
                }
                idx[n] = 0;
            }
        }
    }
    t.sum_duplicates();
    t
}

fn main() {
    let dims = [400u64, 300, 200];
    let true_rank = 4;
    println!("planting a rank-{true_rank} block-structured tensor {dims:?} ...");
    let t = planted_block_low_rank(&dims, true_rank, 28, 1e-3, 99);
    println!("nnz = {}, ‖X‖ = {:.3}\n", t.nnz(), t.norm());

    let engine = MttkrpEngine::from_coo(&t, Profile::a100());
    let mut fits = Vec::new();
    for rank in [1usize, 2, 4, 8] {
        let rep = engine.cp_als(CpAlsOptions {
            rank,
            max_iters: 60,
            tol: 1e-7,
            threads: blco::util::pool::default_threads(),
            seed: 7,
        });
        let fit = *rep.fits.last().unwrap();
        fits.push((rank, fit));
        println!(
            "rank {rank:>2}: fit {fit:.4} after {:>2} iters \
             ({:.2}s total, {:.2}s in MTTKRP)",
            rep.iterations, rep.total_seconds, rep.mttkrp_seconds,
        );
    }
    // the planted rank explains (nearly) all energy; lower ranks cannot
    let fit_at_true = fits.iter().find(|(r, _)| *r == true_rank).unwrap().1;
    let fit_at_one = fits[0].1;
    assert!(fit_at_true > 0.95, "rank-{true_rank} fit {fit_at_true}");
    assert!(fit_at_one < fit_at_true, "rank sweep should improve the fit");
    println!("\nfit saturates at the planted rank ✓ (R={true_rank}: {fit_at_true:.4})");
}
