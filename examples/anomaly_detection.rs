//! Domain example — network-flow anomaly detection with tensor
//! decomposition (the paper's cybersecurity motivation, cf. DARPA in
//! Table 2): build a (source × destination × time) flow tensor with
//! normal low-rank traffic structure plus planted *point anomalies*
//! (scattered one-off heavy flows — deliberately NOT low-rank, so the
//! CP model cannot absorb them), decompose with CP-ALS on the BLCO
//! engine, and flag anomalies by reconstruction residual. Also demos TTV:
//! collapsing the time mode to a traffic heat map.
//!
//!     cargo run --release --example anomaly_detection

use blco::coordinator::engine::MttkrpEngine;
use blco::cpals::CpAlsOptions;
use blco::device::Profile;
use blco::ops::ttv::ttv;
use blco::tensor::coo::CooTensor;
use blco::util::prng::Rng;

const SRC: u64 = 500;
const DST: u64 = 500;
const TIME: u64 = 96; // "15-minute bins over a day"

/// Normal traffic: a few service clusters (many sources → few servers) with
/// a daily activity profile; plus a planted burst: one source hammering one
/// unusual destination in a short window.
fn build_traffic(seed: u64) -> (CooTensor, Vec<(u32, u32, u32)>) {
    let mut rng = Rng::new(seed);
    let mut t = CooTensor::new(&[SRC, DST, TIME]);
    // 8 service clusters
    for _ in 0..60_000 {
        let service = rng.below(8) as u32;
        let dst = service * 3 + rng.below(3) as u32; // 24 hot servers
        let src = rng.zipf(SRC, 0.8) as u32;
        // diurnal profile: busy mid-day bins
        let time = (40.0 + 20.0 * rng.normal()).clamp(0.0, TIME as f64 - 1.0) as u32;
        t.push(&[src, dst, time], 1.0 + rng.f64());
    }
    // point anomalies: scattered one-off heavy flows (no shared structure)
    let mut planted = Vec::new();
    for _ in 0..60 {
        let c = (
            rng.below(SRC) as u32,
            (100 + rng.below(DST - 100)) as u32, // away from the hot servers
            rng.below(TIME) as u32,
        );
        t.push(&[c.0, c.1, c.2], 25.0 + rng.f64());
        planted.push(c);
    }
    t.sum_duplicates();
    (t, planted)
}

fn main() {
    let (t, planted) = build_traffic(2024);
    println!(
        "flow tensor {}x{}x{}: {} nnz ({} planted burst cells)",
        SRC, DST, TIME, t.nnz(),
        planted.len()
    );

    // decompose the structured traffic
    let engine = MttkrpEngine::from_coo(&t, Profile::a100());
    let rep = engine.cp_als(CpAlsOptions {
        rank: 8,
        max_iters: 25,
        tol: 1e-6,
        ..Default::default()
    });
    println!(
        "CP-ALS rank 8: fit {:.4} in {} iters ({:.2}s)",
        rep.fits.last().unwrap(),
        rep.iterations,
        rep.total_seconds
    );

    // anomaly score = |x - x̂| for every observed cell
    let recon = |c: &[u32]| -> f64 {
        let mut v = 0.0;
        for k in 0..8 {
            v += rep.lambda[k]
                * rep.factors[0].row(c[0] as usize)[k]
                * rep.factors[1].row(c[1] as usize)[k]
                * rep.factors[2].row(c[2] as usize)[k];
        }
        v
    };
    let mut scores: Vec<(f64, Vec<u32>)> = (0..t.nnz())
        .map(|e| {
            let c = t.coord(e);
            ((t.vals[e] - recon(&c)).abs(), c)
        })
        .collect();
    scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    // precision@K against the planted set
    let k = planted.len();
    let hits = scores[..k]
        .iter()
        .filter(|(_, c)| planted.contains(&(c[0], c[1], c[2])))
        .count();
    let precision = hits as f64 / k as f64;
    println!(
        "top-{k} anomaly scores: {hits} are planted anomalies \
         (precision@{k} = {precision:.2})"
    );
    println!("top 5:");
    for (s, c) in &scores[..5] {
        println!("  src {:>3} -> dst {:>3} @ bin {:>2}   score {s:.2}", c[0], c[1], c[2]);
    }
    assert!(precision > 0.8, "detector missed the planted anomalies");

    // TTV bonus: collapse the time mode around the strongest anomaly to
    // get that window's (src, dst) heat map straight from the BLCO copy
    let anom = &scores[0].1;
    let mut window = vec![0.0f64; TIME as usize];
    window[anom[2] as usize] = 1.0;
    let heat = ttv(&engine.tensor(), 2, &window, 4);
    let mut top: Vec<(f64, Vec<u32>)> =
        (0..heat.nnz()).map(|e| (heat.vals[e], heat.coord(e))).collect();
    top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!(
        "\nTTV heat map of bin {}: hottest pair src {} -> dst {} ({:.0} units)",
        anom[2], top[0].1[0], top[0].1[1], top[0].0
    );
    assert_eq!(top[0].1[0], anom[0], "heat map agrees with the residual detector");
    println!("the planted anomaly stands out ✓");
}
