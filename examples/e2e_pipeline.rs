//! End-to-end three-layer driver (the repository's composition proof):
//!
//!   L1  Pallas block-MTTKRP kernel (python/compile/kernels/) —
//!   L2  JAX block graph, AOT-lowered to HLO text (`make artifacts`) —
//!   L3  this Rust coordinator: builds BLCO, loads the artifacts through
//!       PJRT, and runs a full CP-ALS decomposition where EVERY MTTKRP
//!       executes inside the AOT-compiled XLA executable. Python is not
//!       running anywhere in this process.
//!
//! The run trains a rank-32 CP model on the demo tensor, logs the fit
//! curve, and cross-checks the PJRT backend against the pure-Rust engine.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use blco::cpals::als::{cp_als, CpAlsOptions};
use blco::device::{Counters, Profile};
use blco::format::blco::BlcoTensor;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::mttkrp::Mttkrp;
use blco::runtime::{artifacts, PjrtRuntime};
use blco::tensor::datasets;

/// Adapter: drive CP-ALS with MTTKRPs executed by the AOT/PJRT executable.
struct PjrtEngine {
    rt: PjrtRuntime,
    t: BlcoTensor,
}

impl Mttkrp for PjrtEngine {
    fn name(&self) -> String {
        "blco-pjrt".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        _threads: usize,
        counters: &Counters,
    ) {
        self.rt
            .mttkrp_fused(&self.t, target, factors, out, counters)
            .expect("PJRT execution failed");
    }
}

fn main() {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = PjrtRuntime::new(&dir).expect("PJRT runtime");
    println!("PJRT platform: {} | artifacts: {} variants", rt.platform(), rt.artifacts.variants.len());

    let preset = datasets::demo3();
    println!("building {} ({} nnz requested) ...", preset.name, preset.nnz);
    let t = preset.build();
    let blco = BlcoTensor::from_coo(&t);
    println!(
        "BLCO: {} blocks / {} batches, {:.1} MiB",
        blco.blocks.len(),
        blco.batches.len(),
        blco.footprint_bytes() as f64 / (1 << 20) as f64
    );

    // --- cross-check the two backends on one MTTKRP first
    let factors = random_factors(&t.dims, 32, 3);
    let pjrt = PjrtEngine { rt, t: blco.clone() };
    let rust = BlcoEngine::new(blco, Profile::a100());
    let mut m_pjrt = Matrix::zeros(t.dims[0] as usize, 32);
    let mut m_rust = Matrix::zeros(t.dims[0] as usize, 32);
    let c = Counters::new();
    let w0 = std::time::Instant::now();
    pjrt.mttkrp(0, &factors, &mut m_pjrt, 1, &c);
    let pjrt_time = w0.elapsed();
    let w0 = std::time::Instant::now();
    rust.mttkrp(0, &factors, &mut m_rust, 8, &Counters::new());
    let rust_time = w0.elapsed();
    let rel = m_pjrt.max_abs_diff(&m_rust) / m_rust.norm().max(1.0);
    println!(
        "backend cross-check: rel diff {rel:.2e} (f32 kernel vs f64 engine) ✓ \
         | pjrt {:.1} ms ({} launches), rust {:.1} ms",
        pjrt_time.as_secs_f64() * 1e3,
        c.snapshot().launches,
        rust_time.as_secs_f64() * 1e3,
    );
    assert!(rel < 1e-4);

    // --- full CP-ALS with every MTTKRP inside the XLA executable
    println!("\nCP-ALS rank 32, all MTTKRPs through the AOT executable:");
    let counters = Counters::new();
    let rep = cp_als(
        &pjrt,
        &t.dims,
        t.norm(),
        CpAlsOptions { rank: 32, max_iters: 10, tol: 1e-6, threads: 1, seed: 1 },
        &counters,
    );
    for (i, f) in rep.fits.iter().enumerate() {
        println!("  iter {:>2}: fit = {f:.6}", i + 1);
    }
    println!(
        "\n{} iterations, {:.2}s total ({:.2}s MTTKRP, {} kernel launches)",
        rep.iterations,
        rep.total_seconds,
        rep.mttkrp_seconds,
        counters.snapshot().launches,
    );
    let first = rep.fits[0];
    let last = *rep.fits.last().unwrap();
    assert!(last > first, "fit must improve: {first} -> {last}");
    println!("fit improved {first:.4} → {last:.4} ✓ — three layers compose");
}
