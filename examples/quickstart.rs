//! Quickstart: build a sparse tensor, convert it to BLCO, run a mode-wise
//! MTTKRP on a simulated A100, and inspect what the engine did.
//!
//!     cargo run --release --example quickstart

use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::device::model::{device_time, throughput_tbps};
use blco::device::Profile;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::tensor::synth;
use blco::util::timer::fmt_duration;

fn main() {
    // 1. A sparse 3-order tensor: 200k non-zeros clustered into mode-2
    //    fibers (the shape class the paper's NELL-2 represents).
    let dims = [3000u64, 2300, 7200];
    println!("generating 200k-nnz synthetic tensor {dims:?} ...");
    let t = synth::fiber_clustered(&dims, 200_000, 2, 1.1, 42);

    // 2. Convert to BLCO + bind to a device profile. The engine decides
    //    in-memory vs out-of-memory and the conflict-resolution strategy
    //    (§5.3) per target mode.
    let engine = MttkrpEngine::from_coo(&t, Profile::a100());
    let b = engine.tensor();
    println!(
        "BLCO: {} bits/index ({} in-block + {} key), {} block(s), {} batch(es), {:.1} MiB",
        b.spec.alto.total_bits,
        b.spec.total_inblock_bits,
        b.spec.total_key_bits,
        b.blocks.len(),
        b.batches.len(),
        b.footprint_bytes() as f64 / (1 << 20) as f64,
    );

    // 3. Rank-32 MTTKRP on every mode.
    let factors = random_factors(&t.dims, 32, 7);
    for mode in 0..3 {
        engine.counters.reset();
        let w0 = std::time::Instant::now();
        let (m, path) = engine.mttkrp(mode, &factors);
        let wall = w0.elapsed();
        let snap = engine.counters.snapshot();
        let model = device_time(&snap, &engine.eng.profile).total();
        println!(
            "mode {mode}: path {:?}  wall {}  modelled {:.3} ms  \
             volume {:.2} GB  TP {:.2} TB/s  atomics {}",
            match path {
                ExecPath::InMemory(r) => format!("{r:?}"),
                ExecPath::Streamed(_) => "streamed".into(),
                ExecPath::Clustered(rep) => format!("cluster×{}", rep.devices),
            },
            fmt_duration(wall),
            model * 1e3,
            snap.volume_bytes() as f64 / 1e9,
            throughput_tbps(snap.volume_bytes(), model),
            snap.atomics,
        );
        // sanity: agree with the serial oracle
        let expect = mttkrp_oracle(&t, mode, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-8);
    }
    println!("all modes verified against the serial oracle ✓");
}
