"""L1/L2 performance analysis (DESIGN.md §8, EXPERIMENTS.md §Perf).

Interpret-mode wall time is NOT a TPU proxy, so this reports *structural*
metrics of the AOT artifacts instead:

* per-variant VMEM footprint estimate of one Pallas grid step (must stay
  far below a TPU core's ~16 MiB, with headroom for double buffering);
* HLO operator census of the lowered module — fusion quality, number of
  gathers/scatters, absence of reshape/transpose churn;
* arithmetic intensity of the block computation (FLOPs per HBM byte) and
  the implied roofline bound.

Usage:  python -m compile.perf_report
"""

from __future__ import annotations

import collections
import re

import jax

jax.config.update("jax_enable_x64", True)

from . import aot, model  # noqa: E402
from .config import default_variants  # noqa: E402
from .kernels.blco_mttkrp import TILE, vmem_estimate_bytes  # noqa: E402


def hlo_census(text: str) -> collections.Counter:
    ops = collections.Counter()
    for line in text.splitlines():
        m = re.match(r"\s*(%?[\w.-]+)\s*=\s*\S+\s+(\w+)\(", line)
        if m:
            ops[m.group(2)] += 1
    return ops


def analyze(v) -> dict:
    text = aot.to_hlo_text(model.lower(v))
    ops = hlo_census(text)
    esize = 4 if v.dtype == "float32" else 8
    # per grid step: stream TILE lidx (8B) + vals, gather (order-1) rows,
    # write TILE partial rows; FLOPs: (order-1) multiplies per rank lane
    bytes_hbm = TILE * (8 + esize) + (v.order - 1) * TILE * v.rank * esize \
        + TILE * v.rank * esize
    flops = TILE * v.rank * (v.order - 1)
    return {
        "name": v.name,
        "vmem": vmem_estimate_bytes(v),
        "ops": ops,
        "intensity": flops / bytes_hbm,
        "hlo_bytes": len(text),
    }


def main() -> None:
    print(f"{'variant':<22} {'VMEM/step':>10} {'AI(fl/B)':>9} "
          f"{'fusions':>8} {'gathers':>8} {'scatters':>9} {'transposes':>11}")
    worst_vmem = 0
    for v in default_variants():
        r = analyze(v)
        worst_vmem = max(worst_vmem, r["vmem"])
        print(
            f"{r['name']:<22} {r['vmem']/1024:>8.1f}Ki {r['intensity']:>9.3f} "
            f"{r['ops'].get('fusion', 0):>8} {r['ops'].get('gather', 0):>8} "
            f"{r['ops'].get('scatter', 0):>9} {r['ops'].get('transpose', 0):>11}"
        )
    budget = 16 * 1024 * 1024
    print(
        f"\nworst-case VMEM/grid-step: {worst_vmem/1024:.1f} KiB "
        f"({worst_vmem/budget*100:.1f}% of a 16 MiB TPU core — "
        f"{budget//max(worst_vmem,1)}x headroom for double buffering)"
    )
    print(
        "arithmetic intensity ~0.1 fl/B → memory-bound, as the paper says; "
        "the roofline is the HBM stream+gather bound, matching the Rust "
        "device model's accounting."
    )


if __name__ == "__main__":
    main()
