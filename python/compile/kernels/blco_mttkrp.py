"""L1 — the BLCO block-MTTKRP Pallas kernel.

This is the compute hot-spot of the paper (Section 5, the "computing phase"):
for every non-zero element in a BLCO block, de-linearize its re-encoded index
with shift/mask (Section 4.1), gather the N-1 non-target factor rows, form
their rank-wise Hadamard product and scale by the non-zero value.

Hardware adaptation (GPU -> TPU, see DESIGN.md §Hardware-Adaptation): the
paper's warp-level segmented scan and global atomics do not exist on TPUs, so
the kernel produces *dense, coalesced* per-nnz partial rows plus the decoded
target coordinates; the conflict resolution (merge) happens either in-graph
via ``segment_sum`` (the fused L2 variant) or in the Rust coordinator. The
nnz stream is tiled by ``BlockSpec`` — the HBM->VMEM block copies play the
role of the paper's coalesced global loads, and the rank dimension is the
vector lane dimension instead of a thread mapping.

The kernel must be lowered with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import Variant

# nnz tile processed per grid step. 256 elements x rank 32 keeps the live
# VMEM working set (lidx + vals + partials + gathered rows) around
# 256*32*4*3 + small ≈ 100 KiB — far below the ~16 MiB VMEM of a TPU core,
# leaving room for double-buffered factor tiles.
TILE = 256


def _delinearize(l, v: Variant, bases_ref):
    """Decode every mode coordinate of the (TILE,) int64 vector ``l``.

    Each coordinate only needs a shift and a mask (the whole point of the
    BLCO re-encoding) and is independent of the others, exposing ILP. The
    per-block base offsets (the adaptive-blocking key of Section 4.2,
    de-composed into per-mode row bases by the coordinator) are added so the
    gathers below address the *global* factor rows.
    """
    coords = []
    for n in range(v.order):
        c = (l >> v.offsets[n]) & v.masks[n]
        coords.append(c.astype(jnp.int32) + bases_ref[n])
    return coords


def _partials_kernel(v: Variant, lidx_ref, vals_ref, bases_ref, *refs):
    factor_refs = refs[: v.order]
    partials_ref, tgt_ref = refs[v.order], refs[v.order + 1]

    l = lidx_ref[...]  # (TILE,) int64, coalesced load
    coords = _delinearize(l, v, bases_ref)

    # Rank-wise product, vectorized over the lane (rank) dimension.
    acc = vals_ref[...][:, None].astype(v.jdtype)  # (TILE, 1)
    acc = jnp.broadcast_to(acc, (l.shape[0], v.rank))
    for n in range(v.order):
        if n == v.target:
            continue
        rows = jnp.take(factor_refs[n][...], coords[n], axis=0)  # (TILE, R)
        acc = acc * rows
    partials_ref[...] = acc
    tgt_ref[...] = coords[v.target]


def block_partials(v: Variant):
    """Build the per-block partials function for variant ``v``.

    Signature: ``(lidx i64[C], vals dt[C], bases i32[N], *factors dt[D_n,R])
    -> (partials dt[C,R], tgt i32[C])``. Padding entries must carry
    ``vals == 0`` so their partial rows are exactly zero.
    """
    assert v.capacity % TILE == 0, (v.capacity, TILE)
    grid = (v.capacity // TILE,)

    in_specs = [
        pl.BlockSpec((TILE,), lambda i: (i,)),  # lidx: streamed tile
        pl.BlockSpec((TILE,), lambda i: (i,)),  # vals: streamed tile
        pl.BlockSpec((v.order,), lambda i: (0,)),  # bases: replicated
    ]
    for d in v.dims:
        # Factor matrices are gathered from in full. On a real TPU these
        # would be tiled/streamed too; under interpret=True the whole-array
        # block keeps the oracle comparison exact.
        in_specs.append(pl.BlockSpec((d, v.rank), lambda i: (0, 0)))

    out_specs = [
        pl.BlockSpec((TILE, v.rank), lambda i: (i, 0)),
        pl.BlockSpec((TILE,), lambda i: (i,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((v.capacity, v.rank), v.jdtype),
        jax.ShapeDtypeStruct((v.capacity,), jnp.int32),
    ]

    kernel = functools.partial(_partials_kernel, v)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )

    def run(lidx, vals, bases, *factors):
        assert len(factors) == v.order
        return fn(lidx, vals, bases, *factors)

    return run


def vmem_estimate_bytes(v: Variant) -> int:
    """Static VMEM footprint estimate of one grid step (for DESIGN.md §Perf).

    Counts the streamed tiles plus the gathered rows and the output tile;
    whole-factor residency is excluded because on real hardware factors are
    HBM-resident and rows are gathered on demand.
    """
    esize = 4 if v.dtype == "float32" else 8
    lidx = TILE * 8
    vals = TILE * esize
    gathered = (v.order - 1) * TILE * v.rank * esize
    out = TILE * v.rank * esize + TILE * 4
    return lidx + vals + gathered + out
