"""Pure-numpy/jnp oracle for the BLCO block-MTTKRP kernel and sparse MTTKRP.

Everything here is written in the most obvious way possible; correctness of
the Pallas kernel (kernels/blco_mttkrp.py), the L2 model (model.py) and — via
golden files — the Rust engines is established against these functions.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from ..config import Variant


def delinearize_ref(lidx, v: Variant, bases):
    """Decode global coordinates from in-block indices, numpy semantics."""
    lidx = np.asarray(lidx, dtype=np.int64)
    coords = []
    for n in range(v.order):
        c = (lidx >> np.int64(v.offsets[n])) & np.int64(v.masks[n])
        coords.append(c.astype(np.int32) + np.int32(bases[n]))
    return coords


def partials_ref(lidx, vals, bases, factors: Sequence, v: Variant):
    """Oracle for the partials kernel: (C,R) rank-wise rows + target ids."""
    coords = delinearize_ref(lidx, v, bases)
    acc = np.asarray(vals)[:, None].astype(v.dtype) * np.ones(
        (len(lidx), v.rank), dtype=v.dtype
    )
    for n in range(v.order):
        if n == v.target:
            continue
        acc = acc * np.asarray(factors[n])[coords[n], :]
    return acc, coords[v.target]


def fused_ref(lidx, vals, bases, factors: Sequence, v: Variant):
    """Oracle for the fused variant: dense M (dims[target], rank)."""
    partials, tgt = partials_ref(lidx, vals, bases, factors, v)
    out = np.zeros((v.dims[v.target], v.rank), dtype=v.dtype)
    np.add.at(out, tgt, partials)
    return out


def mttkrp_coo_ref(coords, vals, factors: Sequence, target: int, out_rows: int):
    """Textbook sparse MTTKRP straight from COO (Figure 3 of the paper).

    ``coords``: (nnz, N) integer array; ``vals``: (nnz,); ``factors[n]``:
    (I_n, R). Returns M with shape (out_rows, R).
    """
    coords = np.asarray(coords)
    vals = np.asarray(vals)
    nnz, order = coords.shape
    rank = np.asarray(factors[0]).shape[1]
    dtype = np.asarray(factors[0]).dtype
    out = np.zeros((out_rows, rank), dtype=dtype)
    for e in range(nnz):
        row = np.full((rank,), vals[e], dtype=dtype)
        for n in range(order):
            if n == target:
                continue
            row = row * np.asarray(factors[n])[coords[e, n], :]
        out[coords[e, target], :] += row
    return out


def mttkrp_dense_ref(dense, factors: Sequence, target: int):
    """Fully dense MTTKRP via explicit matricization + Khatri-Rao product.

    Exponentially expensive; only used on tiny tensors to validate
    ``mttkrp_coo_ref`` itself (the oracle's oracle).
    """
    dense = np.asarray(dense)
    order = dense.ndim
    # Khatri-Rao product of the non-target factors. Ascending mode order with
    # each new factor as the fast row index matches the C-order (row-major)
    # matricization below, where the highest remaining mode varies fastest.
    # The MTTKRP result is invariant to this pairing as long as the
    # matricization and the KRP use the same column ordering.
    others = [n for n in range(order) if n != target]
    kr = None
    for n in others:
        f = np.asarray(factors[n])
        kr = f if kr is None else (kr[:, None, :] * f[None, :, :]).reshape(
            -1, f.shape[1]
        )
    mat = np.moveaxis(dense, target, 0).reshape(dense.shape[target], -1)
    return mat @ kr
