"""AOT driver: lower every model variant to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly.

Run once via ``make artifacts``; Python never executes on the request path.

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--only NAME_SUBSTR]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .config import default_variants  # noqa: E402

MANIFEST = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, only: str | None = None) -> int:
    os.makedirs(out_dir, exist_ok=True)
    lines = []
    n_emitted = 0
    for v in default_variants():
        if only and only not in v.name:
            continue
        t0 = time.time()
        text = to_hlo_text(model.lower(v))
        fname = f"{v.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        lines.append(v.manifest_line(fname))
        n_emitted += 1
        print(
            f"  [{v.name}] {len(text) / 1024:.0f} KiB "
            f"({time.time() - t0:.1f}s)",
            file=sys.stderr,
        )
    with open(os.path.join(out_dir, MANIFEST), "w") as f:
        f.write("\n".join(lines) + "\n")
    return n_emitted


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: file inside out dir")
    ap.add_argument("--only", default=None, help="emit matching variants only")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:  # Makefile passes a representative file path
        out_dir = os.path.dirname(args.out) or "."
    n = emit(out_dir, args.only)
    print(f"emitted {n} variants to {out_dir}")


if __name__ == "__main__":
    main()
