"""Variant configuration shared by the L1 kernel, L2 model, AOT driver and tests.

A *variant* pins every shape the AOT path needs to be static: tensor order,
per-mode padded dimensions, decomposition rank, block capacity (max non-zeros
per BLCO block) and the target mode of the MTTKRP. The in-block linear index
layout (contiguous per-mode bit fields, mode 1 in the uppermost bits — the
BLCO re-encoding of Section 4.1 of the paper) is derived here and must match
``rust/src/linear/encode.rs`` bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import jax

# The AOT interchange uses 64-bit linear indices (the paper's target integer
# size). We keep in-block indices at <= 63 bits so they are representable in a
# non-negative i64 on both sides of the PJRT boundary.
MAX_INBLOCK_BITS = 63


def mode_bits(dim: int) -> int:
    """Bits needed to encode coordinates in ``[0, dim)`` (>= 1)."""
    if dim <= 1:
        return 1
    return max(1, math.ceil(math.log2(dim)))


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled block-MTTKRP computation.

    Attributes:
        name: manifest key, also the artifact file stem.
        dims: *padded* mode lengths; factor matrix ``n`` has shape
            ``(dims[n], rank)``.
        rank: decomposition rank R.
        capacity: max non-zeros per block (entries are zero-padded up to it).
        target: target mode of the MTTKRP (0-based).
        kind: ``"partials"`` (per-nnz rank-wise rows + decoded target ids —
            the L3 coordinator performs the conflict resolution) or
            ``"fused"`` (in-graph segment-sum; returns the dense M matrix).
        dtype: value element type name ("float32" or "float64").
    """

    name: str
    dims: tuple
    rank: int
    capacity: int
    target: int
    kind: str = "partials"
    dtype: str = "float32"

    def __post_init__(self):
        assert self.kind in ("partials", "fused"), self.kind
        assert 0 <= self.target < len(self.dims)
        assert self.capacity > 0 and self.rank > 0
        assert self.inblock_bits <= MAX_INBLOCK_BITS, (
            f"variant {self.name}: {self.inblock_bits} in-block bits > "
            f"{MAX_INBLOCK_BITS}; strip more bits into the block key"
        )

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def bits(self) -> List[int]:
        """Per-mode field widths of the re-encoded in-block index."""
        return [mode_bits(d) for d in self.dims]

    @property
    def offsets(self) -> List[int]:
        """Per-mode shift amounts. Mode 0 occupies the uppermost bits,
        mode N-1 the lowermost (Figure 6b layout)."""
        bits = self.bits
        offs = []
        acc = sum(bits)
        for b in bits:
            acc -= b
            offs.append(acc)
        return offs

    @property
    def masks(self) -> List[int]:
        return [(1 << b) - 1 for b in self.bits]

    @property
    def inblock_bits(self) -> int:
        return sum(self.bits)

    def encode(self, coords: Sequence[int]) -> int:
        """Reference (python) encoder: coords -> in-block linear index."""
        assert len(coords) == self.order
        l = 0
        for c, off, m in zip(coords, self.offsets, self.masks):
            assert 0 <= c <= m, (coords, self.dims)
            l |= (int(c) & m) << off
        return l

    def decode(self, l: int) -> List[int]:
        """Reference (python) decoder: in-block linear index -> coords."""
        return [(int(l) >> off) & m for off, m in zip(self.offsets, self.masks)]

    @property
    def jdtype(self):
        import jax.numpy as jnp

        return {"float32": jnp.float32, "float64": jnp.float64}[self.dtype]

    def input_specs(self):
        """ShapeDtypeStructs of the AOT entry point, in argument order:
        (lidx, vals, bases, factor_0, ..., factor_{N-1})."""
        import jax.numpy as jnp

        specs = [
            jax.ShapeDtypeStruct((self.capacity,), jnp.int64),
            jax.ShapeDtypeStruct((self.capacity,), self.jdtype),
            jax.ShapeDtypeStruct((self.order,), jnp.int32),
        ]
        for d in self.dims:
            specs.append(jax.ShapeDtypeStruct((d, self.rank), self.jdtype))
        return specs

    def manifest_line(self, filename: str) -> str:
        dims = ",".join(str(d) for d in self.dims)
        return (
            f"name={self.name} file={filename} order={self.order} "
            f"rank={self.rank} capacity={self.capacity} target={self.target} "
            f"kind={self.kind} dtype={self.dtype} dims={dims}"
        )


def default_variants() -> List[Variant]:
    """The variant set built by ``make artifacts``.

    One (partials, fused) pair per target mode for the 3-order demo shape and
    a partials-only set for the 4-order shape. The demo shapes match the
    synthetic presets used by the runtime examples/tests (tensors are padded
    up to these dims on the Rust side).
    """
    out: List[Variant] = []
    dims3 = (1024, 1024, 1024)
    for t in range(3):
        out.append(
            Variant(f"m3r32_t{t}_partials", dims3, 32, 4096, t, "partials")
        )
        out.append(Variant(f"m3r32_t{t}_fused", dims3, 32, 4096, t, "fused"))
    dims4 = (256, 256, 256, 64)
    for t in range(4):
        out.append(
            Variant(f"m4r32_t{t}_partials", dims4, 32, 4096, t, "partials")
        )
    return out
