"""L2 — the JAX block-MTTKRP compute graph around the L1 Pallas kernel.

Two graph shapes per variant (see config.Variant.kind):

* ``partials`` — run the Pallas kernel and return ``(partials, tgt)``; the
  Rust coordinator performs the conflict resolution (the paper's Section 5
  contribution lives at L3 in this architecture).
* ``fused`` — additionally merge the partial rows in-graph with an unsorted
  ``segment_sum`` over the decoded target coordinates, returning the dense
  MTTKRP result M. This is the single-launch path used when the target
  factor matrix fits on-device.

Python/JAX runs only at build time: ``aot.py`` lowers these functions to HLO
text once; the Rust runtime compiles and executes them via PJRT.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .config import Variant  # noqa: E402
from .kernels import blco_mttkrp  # noqa: E402


def block_partials_fn(v: Variant):
    """(lidx, vals, bases, *factors) -> (partials (C,R), tgt (C,) i32)."""
    kernel = blco_mttkrp.block_partials(v)

    def fn(lidx, vals, bases, *factors):
        partials, tgt = kernel(lidx, vals, bases, *factors)
        return partials, tgt

    return fn


def block_fused_fn(v: Variant):
    """(lidx, vals, bases, *factors) -> M (dims[target], R).

    Padding entries carry ``vals == 0`` so their (zero) partial rows land
    harmlessly on whatever row their decoded index points at.
    """
    kernel = blco_mttkrp.block_partials(v)
    num_rows = v.dims[v.target]

    def fn(lidx, vals, bases, *factors):
        partials, tgt = kernel(lidx, vals, bases, *factors)
        return jax.ops.segment_sum(partials, tgt, num_segments=num_rows)

    return fn


def build_fn(v: Variant):
    return block_fused_fn(v) if v.kind == "fused" else block_partials_fn(v)


def lower(v: Variant):
    """AOT-lower variant ``v`` with its static input specs."""
    fn = build_fn(v)
    return jax.jit(fn).lower(*v.input_specs())
