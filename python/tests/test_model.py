"""L2 correctness: the fused block graph vs textbook MTTKRP oracles,
and the oracle itself vs the fully dense matricized formulation."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

# hypothesis is optional in the offline image; skip (not error) without it
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.config import Variant  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels.blco_mttkrp import TILE  # noqa: E402


def random_coo(dims, nnz, seed, dtype=np.float64):
    """Random COO tensor with *unique* coordinates."""
    rng = np.random.default_rng(seed)
    seen = set()
    coords = []
    while len(coords) < nnz:
        c = tuple(int(rng.integers(0, d)) for d in dims)
        if c not in seen:
            seen.add(c)
            coords.append(c)
    coords = np.array(coords, dtype=np.int64)
    vals = rng.standard_normal(nnz).astype(dtype)
    return coords, vals


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), target=st.integers(0, 2))
def test_coo_ref_matches_dense_ref(seed, target):
    """The sparse oracle agrees with the explicit matricization + KRP."""
    dims = (5, 4, 3)
    coords, vals = random_coo(dims, 20, seed)
    rng = np.random.default_rng(seed + 1)
    factors = [rng.standard_normal((d, 6)) for d in dims]
    dense = np.zeros(dims)
    for c, v in zip(coords, vals):
        dense[tuple(c)] = v
    sparse = ref.mttkrp_coo_ref(coords, vals, factors, target, dims[target])
    full = ref.mttkrp_dense_ref(dense, factors, target)
    np.testing.assert_allclose(sparse, full, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), target=st.integers(0, 3))
def test_coo_ref_matches_dense_ref_4mode(seed, target):
    dims = (4, 3, 3, 2)
    coords, vals = random_coo(dims, 15, seed)
    rng = np.random.default_rng(seed + 1)
    factors = [rng.standard_normal((d, 5)) for d in dims]
    dense = np.zeros(dims)
    for c, v in zip(coords, vals):
        dense[tuple(c)] = v
    sparse = ref.mttkrp_coo_ref(coords, vals, factors, target, dims[target])
    full = ref.mttkrp_dense_ref(dense, factors, target)
    np.testing.assert_allclose(sparse, full, atol=1e-10)


@pytest.mark.parametrize("target", [0, 1, 2])
def test_fused_block_equals_coo_mttkrp(target):
    """End-to-end at the block level: encode a whole COO tensor into one
    block, run the fused graph, compare against the COO oracle."""
    dims = (60, 40, 20)
    v = Variant("e2e", dims, 16, 2 * TILE, target, "fused", "float64")
    coords, vals = random_coo(dims, 300, seed=13)
    rng = np.random.default_rng(99)
    factors = [rng.standard_normal((d, v.rank)) for d in dims]

    lidx = np.array([v.encode(c) for c in coords], dtype=np.int64)
    lidx = np.pad(lidx, (0, v.capacity - len(lidx)))
    pvals = np.pad(vals, (0, v.capacity - len(vals)))
    bases = np.zeros(3, np.int32)

    m = np.asarray(model.build_fn(v)(lidx, pvals, bases, *factors))
    m_ref = ref.mttkrp_coo_ref(coords, vals, factors, target, dims[target])
    np.testing.assert_allclose(m, m_ref, atol=1e-10)


def test_multi_block_partials_merge():
    """Split one tensor across two blocks with different bases; merging the
    partials reproduces the single-tensor MTTKRP — the OOM streaming
    invariant the Rust coordinator relies on."""
    dims = (64, 32, 16)
    v = Variant("mb", (32, 32, 16), 8, TILE, 0, "partials", "float64")
    coords, vals = random_coo(dims, 200, seed=21)
    rng = np.random.default_rng(17)
    factors_global = [rng.standard_normal((d, v.rank)) for d in dims]

    out = np.zeros((dims[0], v.rank))
    fn = model.build_fn(v)
    for half in range(2):  # block by the top bit of mode 0
        sel = (coords[:, 0] // 32) == half
        bc = coords[sel].copy()
        bc[:, 0] -= half * 32
        lidx = np.array([v.encode(c) for c in bc], dtype=np.int64)
        lidx = np.pad(lidx, (0, v.capacity - len(lidx)))
        bv = np.pad(vals[sel], (0, v.capacity - len(vals[sel])))
        bases = np.array([half * 32, 0, 0], np.int32)
        # factor inputs are the 32-row windows this block addresses
        fwin = [
            factors_global[0][half * 32 : half * 32 + 32],
            factors_global[1],
            factors_global[2],
        ]
        partials, tgt = fn(lidx, bv, np.zeros(3, np.int32), *fwin)
        tgt = np.asarray(tgt) + bases[0]
        np.add.at(out, tgt, np.asarray(partials))

    m_ref = ref.mttkrp_coo_ref(coords, vals, factors_global, 0, dims[0])
    np.testing.assert_allclose(out, m_ref, atol=1e-10)
