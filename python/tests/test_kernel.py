"""L1 correctness: the Pallas block-MTTKRP kernel vs the pure-numpy oracle.

Hypothesis sweeps tensor order, mode widths, rank, block fill level, base
offsets (the adaptive-blocking key path) and dtype; every case asserts
allclose against kernels/ref.py.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

# hypothesis is optional in the offline image; skip (not error) without it
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.config import Variant, mode_bits  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels.blco_mttkrp import TILE, vmem_estimate_bytes  # noqa: E402


def make_case(v: Variant, nnz: int, seed: int, bases=None):
    """Random padded block + factors for variant ``v``."""
    rng = np.random.default_rng(seed)
    bases = np.zeros(v.order, np.int32) if bases is None else np.asarray(bases, np.int32)
    # in-block coordinate range must stay within the factor matrix after the
    # base offset is applied
    coords = np.stack(
        [rng.integers(0, max(1, d - b), size=nnz) for d, b in zip(v.dims, bases)],
        axis=1,
    )
    lidx = np.array([v.encode(c) for c in coords], dtype=np.int64)
    lidx = np.pad(lidx, (0, v.capacity - nnz))
    dt = np.float32 if v.dtype == "float32" else np.float64
    vals = np.pad(rng.standard_normal(nnz).astype(dt), (0, v.capacity - nnz))
    factors = [rng.standard_normal((d, v.rank)).astype(dt) for d in v.dims]
    return lidx, vals, bases, factors


def tol(v: Variant):
    return dict(atol=1e-5, rtol=1e-5) if v.dtype == "float32" else dict(atol=1e-11, rtol=1e-11)


# ---------------------------------------------------------------- hypothesis

variant_strategy = st.builds(
    lambda dims, rank, target_frac, dtype: Variant(
        "h",
        tuple(dims),
        rank,
        TILE,  # one tile per grid step keeps hypothesis cases fast
        min(int(target_frac * len(dims)), len(dims) - 1),
        "partials",
        dtype,
    ),
    dims=st.lists(st.integers(2, 64), min_size=3, max_size=4),
    rank=st.sampled_from([4, 8, 32]),
    target_frac=st.floats(0.0, 0.999),
    dtype=st.sampled_from(["float32", "float64"]),
)


@settings(max_examples=30, deadline=None)
@given(v=variant_strategy, nnz_frac=st.floats(0.0, 1.0), seed=st.integers(0, 2**31))
def test_partials_matches_ref(v, nnz_frac, seed):
    nnz = max(1, int(nnz_frac * v.capacity))
    lidx, vals, bases, factors = make_case(v, nnz, seed)
    partials, tgt = model.build_fn(v)(lidx, vals, bases, *factors)
    p_ref, t_ref = ref.partials_ref(lidx, vals, bases, factors, v)
    np.testing.assert_allclose(np.asarray(partials), p_ref, **tol(v))
    np.testing.assert_array_equal(np.asarray(tgt), t_ref)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), target=st.integers(0, 2))
def test_fused_matches_ref(seed, target):
    v = Variant("hf", (40, 24, 12), 8, TILE, target, "fused")
    lidx, vals, bases, factors = make_case(v, 200, seed)
    m = model.build_fn(v)(lidx, vals, bases, *factors)
    m_ref = ref.fused_ref(lidx, vals, bases, factors, v)
    np.testing.assert_allclose(np.asarray(m), m_ref, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_block_bases_shift_rows(seed):
    """The adaptive-blocking path: non-zero per-mode bases address the right
    global factor rows (block key decomposed into row offsets)."""
    v = Variant("hb", (64, 32, 16), 8, TILE, 0, "partials")
    bases = np.array([32, 16, 8], np.int32)
    lidx, vals, _, factors = make_case(v, 100, seed, bases=bases)
    partials, tgt = model.build_fn(v)(lidx, vals, bases, *factors)
    p_ref, t_ref = ref.partials_ref(lidx, vals, bases, factors, v)
    np.testing.assert_allclose(np.asarray(partials), p_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(tgt), t_ref)
    assert t_ref.min() >= 32  # bases actually applied


# ------------------------------------------------------------------- pinned


@pytest.mark.parametrize("target", [0, 1, 2])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_three_mode_all_targets(target, dtype):
    v = Variant("p3", (50, 30, 20), 16, 2 * TILE, target, "partials", dtype)
    lidx, vals, bases, factors = make_case(v, 400, seed=7)
    partials, tgt = model.build_fn(v)(lidx, vals, bases, *factors)
    p_ref, t_ref = ref.partials_ref(lidx, vals, bases, factors, v)
    np.testing.assert_allclose(np.asarray(partials), p_ref, **tol(v))
    np.testing.assert_array_equal(np.asarray(tgt), t_ref)


@pytest.mark.parametrize("target", [0, 1, 2, 3])
def test_four_mode_all_targets(target):
    v = Variant("p4", (20, 16, 12, 8), 8, TILE, target, "partials")
    lidx, vals, bases, factors = make_case(v, 150, seed=11)
    partials, tgt = model.build_fn(v)(lidx, vals, bases, *factors)
    p_ref, t_ref = ref.partials_ref(lidx, vals, bases, factors, v)
    np.testing.assert_allclose(np.asarray(partials), p_ref, **tol(v))
    np.testing.assert_array_equal(np.asarray(tgt), t_ref)


def test_padding_contributes_zero():
    """Zero-valued padding entries must not perturb the fused result."""
    v = Variant("pad", (16, 16, 16), 4, TILE, 0, "fused")
    lidx, vals, bases, factors = make_case(v, 3, seed=3)
    m = model.build_fn(v)(lidx, vals, bases, *factors)
    assert np.count_nonzero(np.abs(np.asarray(m)).sum(axis=1)) <= 3


def test_empty_block_is_zero():
    v = Variant("empty", (16, 8, 8), 4, TILE, 1, "fused")
    lidx = np.zeros(v.capacity, np.int64)
    vals = np.zeros(v.capacity, np.float32)
    bases = np.zeros(3, np.int32)
    factors = [np.ones((d, v.rank), np.float32) for d in v.dims]
    m = model.build_fn(v)(lidx, vals, bases, *factors)
    assert np.all(np.asarray(m) == 0.0)


def test_duplicate_coordinates_accumulate():
    """Conflicting updates (same target row) must sum, not overwrite."""
    v = Variant("dup", (8, 8, 8), 4, TILE, 0, "fused")
    c = [2, 3, 4]
    lidx = np.zeros(v.capacity, np.int64)
    lidx[:5] = v.encode(c)
    vals = np.zeros(v.capacity, np.float32)
    vals[:5] = 1.0
    bases = np.zeros(3, np.int32)
    factors = [np.ones((d, v.rank), np.float32) for d in v.dims]
    m = np.asarray(model.build_fn(v)(lidx, vals, bases, *factors))
    np.testing.assert_allclose(m[2], 5.0)


def test_vmem_estimate_reasonable():
    """The static VMEM estimate must stay under a TPU core's ~16 MiB."""
    v = Variant("vm", (1024, 1024, 1024), 32, 4096, 0, "partials")
    assert vmem_estimate_bytes(v) < 16 * 1024 * 1024


def test_encode_decode_roundtrip():
    v = Variant("rt", (100, 7, 33, 2), 4, TILE, 0, "partials")
    rng = np.random.default_rng(5)
    for _ in range(200):
        c = [int(rng.integers(0, d)) for d in v.dims]
        assert v.decode(v.encode(c)) == c


def test_mode_bits():
    assert mode_bits(1) == 1
    assert mode_bits(2) == 1
    assert mode_bits(3) == 2
    assert mode_bits(1024) == 10
    assert mode_bits(1025) == 11
