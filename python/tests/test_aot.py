"""AOT path: variants lower to valid HLO text and the manifest round-trips."""

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.config import Variant, default_variants  # noqa: E402


def test_default_variants_well_formed():
    vs = default_variants()
    names = [v.name for v in vs]
    assert len(names) == len(set(names)), "duplicate variant names"
    # every mode of the 3-order demo shape has both kinds
    for t in range(3):
        assert f"m3r32_t{t}_partials" in names
        assert f"m3r32_t{t}_fused" in names
    for v in vs:
        assert v.capacity % 256 == 0
        assert v.inblock_bits <= 63


def test_lower_one_variant_to_hlo_text():
    v = Variant("aot_smoke", (64, 32, 16), 8, 256, 0, "partials")
    text = aot.to_hlo_text(model.lower(v))
    assert "ENTRY" in text
    assert "HloModule" in text
    # all inputs present: lidx, vals, bases, 3 factors
    assert text.count("parameter(") >= 6


def test_emit_writes_files_and_manifest(tmp_path):
    n = aot.emit(str(tmp_path), only="m3r32_t0")
    assert n == 2  # partials + fused for target 0
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    for line in manifest:
        kv = dict(tok.split("=", 1) for tok in line.split())
        assert set(kv) >= {
            "name", "file", "order", "rank", "capacity", "target", "kind",
            "dtype", "dims",
        }
        path = tmp_path / kv["file"]
        assert path.exists() and path.stat().st_size > 0
        assert "ENTRY" in path.read_text()[:200_000]
        dims = tuple(int(d) for d in kv["dims"].split(","))
        assert len(dims) == int(kv["order"])


def test_manifest_line_format():
    v = Variant("x", (8, 8, 8), 4, 256, 2, "fused", "float64")
    line = v.manifest_line("x.hlo.txt")
    kv = dict(tok.split("=", 1) for tok in line.split())
    assert kv["name"] == "x"
    assert kv["target"] == "2"
    assert kv["kind"] == "fused"
    assert kv["dtype"] == "float64"
    assert kv["dims"] == "8,8,8"


def test_variant_rejects_oversized_inblock_index():
    with pytest.raises(AssertionError):
        Variant("big", (1 << 22, 1 << 22, 1 << 22), 4, 256, 0)
