//! Figure 9: per-mode speedup of BLCO over MM-CSF for every in-memory
//! tensor mode, per device. The paper shows BLCO better-or-comparable on
//! every mode (up to 33×) except on the small cache-resident tensors (Uber,
//! NIPS) where MM-CSF's compression wins some modes.
//!
//!     cargo bench --bench fig9_permode_speedup
//!
//! Env: BLCO_BENCH_PRESETS / BLCO_BENCH_REPS / BLCO_BENCH_DEVICE.

use blco::bench::{banner, bench_reps, measure, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::csf::MmCsfEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::datasets;
use blco::util::pool::default_threads;

fn main() {
    let device = std::env::var("BLCO_BENCH_DEVICE").unwrap_or_else(|_| "a100".into());
    let profile = Profile::by_name(&device).expect("unknown device");
    banner("Figure 9", &format!("per-mode BLCO speedup vs MM-CSF ({device})"));
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;
    let filter: Option<Vec<String>> = std::env::var("BLCO_BENCH_PRESETS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());

    let tbl = Table::new(&[10, 6, 14, 14, 10]);
    tbl.header(&["dataset", "mode", "MM-CSF(ms)", "BLCO(ms)", "speedup"]);
    let mut worst: f64 = f64::INFINITY;
    let mut best: f64 = 0.0;

    for mut preset in datasets::in_memory() {
        if let Some(f) = &filter {
            if !f.iter().any(|x| x == preset.name) {
                continue;
            }
        }
        if smoke() {
            if !matches!(preset.name, "uber" | "vast") {
                continue;
            }
            preset.nnz /= 4;
        }
        let t = preset.build();
        let factors = random_factors(&t.dims, rank, 1);
        let mm = MmCsfEngine::new(&t);
        let bl = BlcoEngine::new(
            BlcoTensor::from_coo_with(&t, preset.blco_config()),
            profile.clone(),
        );
        for mode in 0..t.order() {
            let m_mm =
                measure(&mm, mode, &factors, t.dims[mode] as usize, threads, reps, &profile);
            let m_bl =
                measure(&bl, mode, &factors, t.dims[mode] as usize, threads, reps, &profile);
            let sp = m_mm.model_s / m_bl.model_s;
            worst = worst.min(sp);
            best = best.max(sp);
            tbl.row(&[
                preset.name.to_string(),
                (mode + 1).to_string(),
                format!("{:.3}", m_mm.model_s * 1e3),
                format!("{:.3}", m_bl.model_s * 1e3),
                format!("{sp:.2}x"),
            ]);
        }
    }
    println!("\nrange: {worst:.2}x – {best:.2}x  (paper: ~0.6x on Uber/NIPS up to 33.35x)");
    let mut json = BenchJson::new("fig9_permode_speedup");
    json.metric("worst_mode_speedup", worst);
    json.metric("best_mode_speedup", best);
    json.flush();
}
