//! Figure 1: per-mode MTTKRP execution time of MM-CSF, normalized by the
//! fastest mode, rank 32. The paper shows order-of-magnitude variation on
//! skewed tensors (Uber, Enron, DARPA) because MM-CSF's compression favours
//! some orientations — the motivating figure.
//!
//! Normalization uses the *measured wall time* of the real parallel
//! execution: the per-mode variance comes from traversal imbalance and
//! contention, which the byte-level device model deliberately averages out
//! (it has no warp-imbalance term) — see EXPERIMENTS.md.
//!
//!     cargo bench --bench fig1_mode_variation

use blco::bench::{banner, bench_reps, measure, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::mttkrp::csf::MmCsfEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::datasets;
use blco::util::pool::default_threads;

fn main() {
    banner("Figure 1", "MM-CSF per-mode time, normalized to fastest mode");
    let profile = Profile::a100();
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;

    let tbl = Table::new(&[10, 6, 14, 14, 12]);
    tbl.header(&["dataset", "mode", "model(ms)", "wall(ms)", "normalized"]);
    let mut json = BenchJson::new("fig1_mode_variation");

    let names: &[&str] =
        if smoke() { &["uber"] } else { &["nell2", "uber", "enron", "darpa"] };
    for &name in names {
        let mut preset = datasets::by_name(name).unwrap();
        if smoke() {
            preset.nnz /= 4;
        }
        let t = preset.build();
        let factors = random_factors(&t.dims, rank, 1);
        let eng = MmCsfEngine::new(&t);
        let ms: Vec<_> = (0..t.order())
            .map(|m| {
                measure(&eng, m, &factors, t.dims[m] as usize, threads, reps, &profile)
            })
            .collect();
        let fastest = ms
            .iter()
            .map(|m| m.wall.as_secs_f64())
            .fold(f64::INFINITY, f64::min);
        for (mode, m) in ms.iter().enumerate() {
            tbl.row(&[
                name.to_string(),
                (mode + 1).to_string(),
                format!("{:.3}", m.model_s * 1e3),
                format!("{:.3}", m.wall.as_secs_f64() * 1e3),
                format!("{:.2}x", m.wall.as_secs_f64() / fastest),
            ]);
        }
        let worst =
            ms.iter().map(|m| m.wall.as_secs_f64()).fold(0.0, f64::max) / fastest;
        println!("  -> {name}: worst/best = {worst:.2}x  (paper: 2-12x depending on dataset)\n");
        json.metric(&format!("{name}_worst_over_best"), worst);
        json.metric(&format!("{name}_fastest_mode_wall_ms"), fastest * 1e3);
    }
    json.flush();
}
