//! Figure 12: breakdown of the BLCO construction pipeline per stage —
//! linearize, sort, re-encode, block, batch. The paper's claim: the
//! GPU-specific stages (re-encode + block + batch, which ALTO does not
//! need) cost less than 25% of the total.
//!
//!     cargo bench --bench fig12_construction_breakdown

use blco::bench::{banner, smoke, BenchJson, Table};
use blco::format::blco::BlcoTensor;
use blco::tensor::datasets;

fn main() {
    banner("Figure 12", "BLCO construction cost breakdown (% of total)");
    let tbl = Table::new(&[10, 10, 11, 10, 10, 10, 10, 12]);
    tbl.header(&[
        "dataset", "total(s)", "linearize", "sort", "reencode", "block", "batch", "gpu-extra",
    ]);

    let mut json = BenchJson::new("fig12_construction_breakdown");
    for mut preset in datasets::in_memory() {
        if smoke() {
            if !matches!(preset.name, "nips" | "uber") {
                continue;
            }
            preset.nnz /= 4;
        }
        let t = preset.build();
        let b = BlcoTensor::from_coo_with(&t, preset.blco_config());
        let total = b.stages.total().as_secs_f64();
        let pct = |name: &str| -> f64 {
            b.stages.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0) / total * 100.0
        };
        // the stages ALTO also needs are linearize+sort; the rest is the
        // GPU-specific extra the paper bounds at <25%
        let gpu_extra = pct("reencode") + pct("block") + pct("batch");
        tbl.row(&[
            preset.name.to_string(),
            format!("{total:.3}"),
            format!("{:.1}%", pct("linearize")),
            format!("{:.1}%", pct("sort")),
            format!("{:.1}%", pct("reencode")),
            format!("{:.1}%", pct("block")),
            format!("{:.1}%", pct("batch")),
            format!("{gpu_extra:.1}%"),
        ]);
        json.metric(&format!("{}_total_s", preset.name), total);
        json.metric(&format!("{}_gpu_extra_pct", preset.name), gpu_extra);
    }
    println!("\n(paper: re-encode+block+batch typically < 25% of construction)");
    json.flush();
}
