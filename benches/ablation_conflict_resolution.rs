//! Ablation (DESIGN.md §8): measured wall-clock cost of the three
//! per-batch conflict-resolution strategies the certificate chooses
//! between — `NoSync` (certified waved execution, plain stores),
//! `Privatize` (one private output copy per worker, tree-reduced), and
//! `Atomic` (CAS on every flush) — forced one at a time on the same
//! engine via `BlcoEngine::mttkrp_forced`. These are real threaded runs,
//! not modelled device times.
//!
//! Two certified scenarios:
//!   * `singlewg` — every batch is a single work-group (workgroup >=
//!     batch nnz), so the analyzer proves zero cross-group conflicts and
//!     certifies every batch NoSync. Plain stores do strictly less work
//!     than CAS loops or private-copy merges here, so NoSync must win;
//!     the bench asserts it.
//!   * `clustered` — fiber-clustered tensor under the default blocking,
//!     multi-group batches with real row overlap; reported, not asserted
//!     (the winner depends on how much of the schedule certifies).
//!
//!     cargo bench --bench ablation_conflict_resolution

use std::sync::Arc;

use blco::analysis::conflict::CertificateSet;
use blco::bench::{banner, bench_reps, smoke, BenchJson, Table};
use blco::device::{Counters, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::mttkrp::blco::{BatchStrategy, BlcoEngine};
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::mttkrp::Mttkrp;
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;
use blco::util::pool::default_threads;
use blco::util::timer::time_median;

struct Scenario {
    name: &'static str,
    tensor: CooTensor,
    config: BlcoConfig,
    /// NoSync must be the fastest strategy (enforced with an assert)
    must_win: bool,
}

fn scenarios() -> Vec<Scenario> {
    let (single_nnz, clustered_nnz) =
        if smoke() { (60_000, 60_000) } else { (300_000, 300_000) };
    vec![
        Scenario {
            name: "singlewg",
            // long target mode: the Privatize leg pays threads x rows x
            // rank of private-copy traffic that NoSync skips
            tensor: synth::uniform(&[65_536, 256, 16], single_nnz, 11),
            // workgroup >= max_block_nnz >= nnz: one batch, one group
            config: BlcoConfig {
                max_block_nnz: 1 << 19,
                workgroup: 1 << 19,
                ..Default::default()
            },
            must_win: true,
        },
        Scenario {
            name: "clustered",
            tensor: synth::fiber_clustered(&[4_096, 2_048, 2_048], clustered_nnz, 2, 0.8, 64),
            config: BlcoConfig {
                max_block_nnz: 1 << 14,
                workgroup: 256,
                ..Default::default()
            },
            must_win: false,
        },
    ]
}

fn main() {
    banner(
        "Ablation",
        "forced NoSync / Privatize / Atomic, measured wall-clock (a100)",
    );
    let profile = Profile::a100();
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;
    println!("threads = {threads}, reps = {reps} (median)");

    let tbl = Table::new(&[10, 12, 12, 12, 10, 14, 14]);
    tbl.header(&[
        "scenario", "nosync", "privatize", "atomic", "winner", "nosync batches", "conflict pairs",
    ]);

    let mut json = BenchJson::new("ablation_conflict_resolution");
    for sc in scenarios() {
        let rows = sc.tensor.dims[0] as usize;
        let factors = random_factors(&sc.tensor.dims, rank, 1);
        let eng = BlcoEngine::new(
            BlcoTensor::from_coo_with(&sc.tensor, sc.config),
            profile.clone(),
        );
        let certs = Arc::new(CertificateSet::analyze(&eng.src));
        let cert0 = certs.mode(0);
        let nosync_batches = cert0.no_sync_batches();
        let conflict_pairs = cert0.conflict_pairs();
        if sc.must_win {
            assert_eq!(
                conflict_pairs, 0,
                "{}: single-group batches must certify conflict-free",
                sc.name
            );
        }
        let eng = eng.with_certificates(Arc::clone(&certs));

        // reference bits from the production (certified) path; each forced
        // strategy must agree to fp-reassociation tolerance
        let mut want = Matrix::zeros(rows, rank);
        eng.mttkrp(0, &factors, &mut want, 1, &Counters::new());

        let mut walls = Vec::new();
        for strategy in
            [BatchStrategy::NoSync, BatchStrategy::Privatize, BatchStrategy::Atomic]
        {
            let mut out = Matrix::zeros(rows, rank);
            let wall = time_median(reps, || {
                eng.mttkrp_forced(
                    strategy,
                    0,
                    &factors,
                    &mut out,
                    threads,
                    &Counters::new(),
                );
            });
            let worst = out
                .data
                .iter()
                .zip(&want.data)
                .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
                .fold(0.0f64, f64::max);
            assert!(
                worst < 1e-9,
                "{}: {strategy:?} diverges from the certified result ({worst:e})",
                sc.name
            );
            walls.push(wall.as_secs_f64() * 1e3);
        }
        let (nosync_ms, privatize_ms, atomic_ms) = (walls[0], walls[1], walls[2]);
        let winner = if nosync_ms <= privatize_ms && nosync_ms <= atomic_ms {
            "nosync"
        } else if privatize_ms <= atomic_ms {
            "privatize"
        } else {
            "atomic"
        };
        if sc.must_win {
            assert_eq!(
                winner, "nosync",
                "{}: certified conflict-free schedule must make plain \
                 stores the cheapest strategy (nosync {nosync_ms:.3}ms, \
                 privatize {privatize_ms:.3}ms, atomic {atomic_ms:.3}ms)",
                sc.name
            );
        }

        json.metric(&format!("{}_nosync_wall_ms", sc.name), nosync_ms);
        json.metric(&format!("{}_privatize_wall_ms", sc.name), privatize_ms);
        json.metric(&format!("{}_atomic_wall_ms", sc.name), atomic_ms);
        json.metric(&format!("{}_nosync_batches", sc.name), nosync_batches as f64);
        json.metric(&format!("{}_conflict_pairs", sc.name), conflict_pairs as f64);
        tbl.row(&[
            sc.name.to_string(),
            format!("{nosync_ms:.3}ms"),
            format!("{privatize_ms:.3}ms"),
            format!("{atomic_ms:.3}ms"),
            winner.to_string(),
            nosync_batches.to_string(),
            conflict_pairs.to_string(),
        ]);
    }
    println!(
        "\n(singlewg: the certificate proves the whole schedule \
         conflict-free, so plain stores beat both the CAS loop and the \
         per-thread private copies — the win the static analyzer banks \
         without a runtime check. clustered: real row overlap; waved \
         NoSync pays wave barriers, Atomic pays CAS, Privatize pays \
         threads x rows x rank of merge traffic.)"
    );
    json.flush();
}
