//! Ablation (DESIGN.md §8): the §5.3 adaptation heuristic. Sweep the
//! target-mode length and compare register-based vs hierarchical conflict
//! resolution vs the Auto heuristic — both the `target_len` threshold and
//! the certificate-driven policy from the static conflict analyzer
//! (`blco::analysis`) — plus the idealized mode-sorted list engine
//! (`genten`) as an upper bound on what global sorting (which BLCO
//! deliberately avoids — it would be mode-specific) could buy.
//!
//!     cargo bench --bench ablation_conflict_resolution

use std::sync::Arc;

use blco::analysis::conflict::CertificateSet;
use blco::bench::{banner, bench_reps, measure, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::mttkrp::blco::{BlcoEngine, Resolution};
use blco::mttkrp::genten::GenTenEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::synth;
use blco::util::pool::default_threads;

fn main() {
    banner("Ablation", "conflict resolution vs target-mode length (a100)");
    let profile = Profile::a100();
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;

    let tbl = Table::new(&[10, 12, 12, 12, 12, 12, 14, 14]);
    tbl.header(&[
        "mode-len", "register", "hierarch", "auto", "cert-auto", "sorted-list",
        "heuristic picks", "cert picks",
    ]);

    let mut json = BenchJson::new("ablation_conflict_resolution");
    // fix the other modes, sweep the target length through the SM threshold
    let lens: &[u64] =
        if smoke() { &[16, 512] } else { &[4, 16, 64, 108, 512, 4096, 65536] };
    let sweep_nnz = if smoke() { 60_000 } else { 300_000 };
    for &target_len in lens {
        let dims = [target_len, 3000, 3000];
        let t = synth::fiber_clustered(&dims, sweep_nnz, 2, 0.8, target_len);
        let factors = random_factors(&dims, rank, 1);
        let rows = target_len as usize;

        let make = |r: Resolution| {
            BlcoEngine::new(BlcoTensor::from_coo(&t), profile.clone())
                .with_resolution(r)
        };
        let reg = measure(&make(Resolution::Register), 0, &factors, rows, threads, reps, &profile);
        let hier = measure(&make(Resolution::Hierarchical), 0, &factors, rows, threads, reps, &profile);
        let auto = measure(&make(Resolution::Auto), 0, &factors, rows, threads, reps, &profile);
        let sorted = measure(&GenTenEngine::new(t.clone()), 0, &factors, rows, threads, reps, &profile);

        // the certificate-driven Auto column: analyze once, attach, measure
        let auto_engine = make(Resolution::Auto);
        let certs = Arc::new(CertificateSet::analyze(&auto_engine.src));
        let cert_engine = auto_engine.with_certificates(Arc::clone(&certs));
        let cert_auto = measure(&cert_engine, 0, &factors, rows, threads, reps, &profile);
        let cert0 = certs.mode(0);

        json.metric(&format!("len{target_len}_register_ms"), reg.model_s * 1e3);
        json.metric(&format!("len{target_len}_hierarchical_ms"), hier.model_s * 1e3);
        json.metric(&format!("len{target_len}_auto_ms"), auto.model_s * 1e3);
        json.metric(&format!("len{target_len}_cert_auto_ms"), cert_auto.model_s * 1e3);
        json.metric(
            &format!("len{target_len}_nosync_batches"),
            cert0.no_sync_batches() as f64,
        );
        json.metric(
            &format!("len{target_len}_conflict_pairs"),
            cert0.conflict_pairs() as f64,
        );
        tbl.row(&[
            target_len.to_string(),
            format!("{:.3}ms", reg.model_s * 1e3),
            format!("{:.3}ms", hier.model_s * 1e3),
            format!("{:.3}ms", auto.model_s * 1e3),
            format!("{:.3}ms", cert_auto.model_s * 1e3),
            format!("{:.3}ms", sorted.model_s * 1e3),
            format!("{:?}", make(Resolution::Auto).effective_resolution(0)),
            format!("{:?}", cert_engine.effective_resolution(0)),
        ]);
    }
    println!(
        "\nexpected: hierarchical wins below the SM count (108 on a100), \
         register above; Auto tracks the winner (§5.3). The sorted list is \
         mode-specific — the price BLCO's mode-agnostic design avoids is \
         visible in its construction cost (Figure 11), not here."
    );
    json.flush();
}
