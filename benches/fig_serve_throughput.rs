//! Serving-layer throughput: a seeded mixed-tenant trace replayed over a
//! tenants × devices sweep, full policy (weighted-round-robin fairness +
//! fused streaming) vs the one-job-at-a-time FIFO baseline. The modelled
//! makespan win comes from two places the report makes observable: fleet
//! parallelism (jobs dispatch to the least-loaded device) and fusion
//! (same-`(tensor, mode, rank)` streamed jobs cross the host link once per
//! group — the serving-side answer to Figure 10's interconnect bottleneck).
//!
//!     cargo bench --bench fig_serve_throughput
//!
//! Env: BLCO_BENCH_SERVE_JOBS_PER_TENANT=N jobs per tenant (default 8).

use std::sync::Arc;

use blco::bench::{banner, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::service::{
    serve, synthetic_trace, ServeOptions, TensorRegistry, TraceConfig,
};
use blco::tensor::synth;
use blco::util::pool::default_threads;

fn main() {
    banner(
        "Serving throughput (extension)",
        "multi-tenant trace: batched+fair vs one-job-at-a-time (a100, scaled memory)",
    );
    let threads = default_threads();
    let jobs_per_tenant: usize = std::env::var("BLCO_BENCH_SERVE_JOBS_PER_TENANT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke() { 4 } else { 8 });
    let mut json = BenchJson::new("fig_serve_throughput");

    // one in-memory tensor + one streamed tensor, built once and shared by
    // Arc across every registry in the sweep (the single-copy property)
    let profile = Profile::a100().with_memory(4 << 20);
    println!("building tensors ...");
    let hot = synth::uniform(&[200, 150, 100], 30_000, 11);
    let cold_nnz = if smoke() { 100_000 } else { 300_000 };
    let cold = synth::fiber_clustered(&[2_000, 1_200, 900], cold_nnz, 2, 0.7, 13);
    let hot_b = Arc::new(BlcoTensor::from_coo(&hot));
    let cold_b = Arc::new(BlcoTensor::from_coo_with(
        &cold,
        BlcoConfig { max_block_nnz: 1 << 15, ..Default::default() },
    ));

    let tbl = Table::new(&[8, 4, 9, 14, 14, 9, 10, 10, 12]);
    tbl.header(&[
        "tenants", "D", "policy", "makespan(ms)", "vs naive", "hit rate", "fused", "rejected",
        "mean lat(ms)",
    ]);
    let tenant_sweep: &[usize] = if smoke() { &[2] } else { &[2, 4] };
    let device_sweep: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4] };
    for &tenants in tenant_sweep {
        for &devices in device_sweep {
            let cfg = TraceConfig {
                tenants,
                jobs: jobs_per_tenant * tenants,
                mean_gap_s: 5e-5,
                ranks: vec![16],
                cpals_every: 0,
                seed: 0xA11CE ^ tenants as u64,
            };
            let mut naive_makespan = 0.0f64;
            for batched in [false, true] {
                let mut reg = TensorRegistry::new(profile.clone());
                reg.register_shared("hot", Arc::clone(&hot_b));
                reg.register_shared("cold", Arc::clone(&cold_b));
                let (tenant_list, trace) = synthetic_trace(&reg, &cfg);
                let opts = if batched {
                    ServeOptions::batched(devices, threads)
                } else {
                    ServeOptions::naive(devices, threads)
                };
                let rep = serve(&reg, &tenant_list, &trace, &opts);
                if !batched {
                    naive_makespan = rep.makespan_s;
                }
                json.metric(
                    &format!(
                        "t{tenants}_d{devices}_{}_makespan_s",
                        if batched { "batched" } else { "naive" }
                    ),
                    rep.makespan_s,
                );
                tbl.row(&[
                    tenants.to_string(),
                    devices.to_string(),
                    if batched { "batched" } else { "naive" }.to_string(),
                    format!("{:.3}", rep.makespan_s * 1e3),
                    if batched {
                        format!("{:.2}x", naive_makespan / rep.makespan_s.max(1e-12))
                    } else {
                        "1.00x".to_string()
                    },
                    format!("{:.0}%", rep.cache_hit_rate() * 100.0),
                    format!("{}/{}", rep.fused_groups, rep.fused_jobs),
                    rep.rejected().to_string(),
                    format!("{:.2}", rep.mean_latency_s() * 1e3),
                ]);
            }
        }
    }
    println!(
        "\n(batched: same-(tensor, mode, rank) streamed jobs share one pass, so \
         the tensor crosses the host link once per fused group; the schedule \
         cache turns repeated keys into plan reuse. The naive rows replay the \
         identical trace one job at a time in arrival order.)"
    );
    json.flush();
}
