//! Serving-layer throughput under **open-loop** load: per fleet shape, a
//! seeded Poisson arrival process offers a fixed fraction of the fleet's
//! calibrated capacity — the offered rate does not care how fast the
//! queue drains, so past saturation the backlog (and the latency tail)
//! grows without bound. The sweep walks the load axis and reports the
//! p50/p95/p99 latency at each point plus the **knee**: the highest
//! offered QPS whose p99 still meets the SLO (the paper's Figure-10
//! interconnect story, recast as a serving capacity question — fusion
//! and the schedule cache are what hold the knee up).
//!
//!     cargo bench --bench fig_serve_throughput
//!
//! Env: BLCO_BENCH_SERVE_JOBS_PER_TENANT=N jobs per tenant (default 12).

use std::sync::Arc;

use blco::bench::{banner, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::service::{
    synthetic_trace, ArrivalProcess, ServeRequest, TensorRegistry, TraceConfig,
};
use blco::tensor::synth;
use blco::util::pool::default_threads;

fn main() {
    banner(
        "Serving knee (extension)",
        "open-loop Poisson load sweep: tail latency vs offered QPS per fleet shape",
    );
    let threads = default_threads();
    let jobs_per_tenant: usize = std::env::var("BLCO_BENCH_SERVE_JOBS_PER_TENANT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke() { 8 } else { 12 });
    let mut json = BenchJson::new("fig_serve_throughput");

    // one in-memory tensor + one streamed tensor, built once and shared by
    // Arc across every registry in the sweep (the single-copy property)
    let profile = Profile::a100().with_memory(4 << 20);
    println!("building tensors ...");
    let hot = synth::uniform(&[200, 150, 100], 30_000, 11);
    let cold_nnz = if smoke() { 100_000 } else { 300_000 };
    let cold = synth::fiber_clustered(&[2_000, 1_200, 900], cold_nnz, 2, 0.7, 13);
    let hot_b = Arc::new(BlcoTensor::from_coo(&hot));
    let cold_b = Arc::new(BlcoTensor::from_coo_with(
        &cold,
        BlcoConfig { max_block_nnz: 1 << 15, ..Default::default() },
    ));
    let fresh_reg = || {
        let mut reg = TensorRegistry::new(profile.clone());
        reg.register_shared("hot", Arc::clone(&hot_b));
        reg.register_shared("cold", Arc::clone(&cold_b));
        reg
    };

    let tenants = 2usize;
    let jobs = jobs_per_tenant * tenants;
    // offered load as a fraction of the calibrated fleet capacity; the
    // grid is fixed so the metric names stay stable across runs
    let loads: &[(u32, f64)] = if smoke() {
        &[(50, 0.5), (90, 0.9), (130, 1.3)]
    } else {
        &[(50, 0.5), (80, 0.8), (110, 1.1), (140, 1.4), (170, 1.7)]
    };
    let device_sweep: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4] };

    let tbl = Table::new(&[4, 6, 10, 10, 10, 10, 7, 6, 6]);
    tbl.header(&[
        "D", "load", "qps", "p50(ms)", "p95(ms)", "p99(ms)", "miss%", "maxQ", "knee",
    ]);
    for &devices in device_sweep {
        // calibrate: replay the same job mix closed-loop (t=0 burst) to
        // measure what this fleet shape can drain — capacity in QPS and
        // the mean modelled service time that anchors the SLO
        let reg = fresh_reg();
        let (ten, trace) = synthetic_trace(
            &reg,
            &TraceConfig {
                tenants,
                jobs,
                mean_gap_s: 5e-5,
                ranks: vec![16],
                cpals_every: 0,
                seed: 0xCA11B ^ devices as u64,
                arrival: ArrivalProcess::Bursty,
                deadline_s: None,
            },
        );
        let cal = ServeRequest::new(&reg)
            .trace(&ten, &trace)
            .devices(devices)
            .threads(threads)
            .run()
            .expect("valid request")
            .into_report();
        assert_eq!(cal.rejected(), 0, "calibration trace must be servable");
        let capacity_qps = cal.completed() as f64 / cal.makespan_s.max(1e-12);
        let mean_service_s = cal
            .outcomes
            .iter()
            .map(|o| o.duration_s)
            .sum::<f64>()
            / cal.completed().max(1) as f64;
        // SLO: generous vs one service time, tight vs a growing backlog
        let slo_s = 8.0 * mean_service_s;

        let mut knee_qps = 0.0f64;
        for &(pct, rho) in loads {
            let rate_qps = rho * capacity_qps;
            let reg = fresh_reg();
            let cfg = TraceConfig {
                tenants,
                jobs,
                mean_gap_s: 5e-5,
                ranks: vec![16],
                cpals_every: 0,
                seed: 0x0FE12ED ^ (devices as u64 * 31 + pct as u64),
                arrival: ArrivalProcess::Poisson { rate_qps },
                deadline_s: Some(slo_s),
            };
            let (ten, trace) = synthetic_trace(&reg, &cfg);
            let rep = ServeRequest::new(&reg)
                .trace(&ten, &trace)
                .devices(devices)
                .threads(threads)
                .run()
                .expect("valid request")
                .into_report();
            let p50 = rep.latency.p50 * 1e3;
            let p95 = rep.latency.p95 * 1e3;
            let p99 = rep.latency.p99 * 1e3;
            json.metric(&format!("serve_p50_ms_at_load{pct:03}_d{devices}"), p50);
            json.metric(&format!("serve_p95_ms_at_load{pct:03}_d{devices}"), p95);
            json.metric(&format!("serve_p99_ms_at_load{pct:03}_d{devices}"), p99);
            let sustainable = rep.latency.p99 <= slo_s;
            if sustainable {
                knee_qps = rate_qps;
            }
            tbl.row(&[
                devices.to_string(),
                format!("{:.1}", rho),
                format!("{:.0}", rate_qps),
                format!("{:.3}", p50),
                format!("{:.3}", p95),
                format!("{:.3}", p99),
                format!("{:.0}%", rep.deadline_miss_rate() * 100.0),
                format!("{:.0}", rep.queue_depth.max),
                if sustainable { "ok" } else { "PAST" }.to_string(),
            ]);
        }
        json.metric(&format!("serve_max_qps_d{devices}"), knee_qps);
        println!(
            "  d{devices}: capacity {:.0} qps, max sustainable (p99 <= {:.2} ms) {:.0} qps",
            capacity_qps,
            slo_s * 1e3,
            knee_qps
        );
    }
    println!(
        "\n(open loop: arrivals keep coming at the offered rate no matter how \
         deep the queue gets, so past the knee the p99 column explodes — \
         that cliff, not the mean, is what capacity planning reads. The knee \
         rows are the max sustainable QPS per fleet shape.)"
    );
    json.flush();
}
