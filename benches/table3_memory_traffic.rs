//! Table 3: per-mode memory volume (GB) and effective throughput (TB/s) of
//! BLCO vs MM-CSF on the A100 profile (paper datasets: Uber, Vast-2015,
//! Enron, NELL-1). The paper's finding: MM-CSF moves *less* data (tree
//! compression) but achieves *lower* throughput (irregular access +
//! synchronization), and both of its metrics swing across modes.
//!
//!     cargo bench --bench table3_memory_traffic

use blco::bench::{banner, bench_reps, geomean, measure, smoke, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::csf::MmCsfEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::datasets;
use blco::util::pool::default_threads;

fn main() {
    banner("Table 3", "memory volume + throughput per mode, BLCO vs MM-CSF (a100)");
    let profile = Profile::a100();
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;

    let tbl = Table::new(&[10, 8, 6, 12, 10, 12]);
    tbl.header(&["dataset", "format", "n", "Vol(GB)", "TP(TB/s)", "coalesced"]);

    let mut json = BenchJson::new("table3_memory_traffic");
    let names: &[&str] =
        if smoke() { &["uber"] } else { &["uber", "vast", "enron", "nell1"] };
    for &name in names {
        let mut preset = datasets::by_name(name).unwrap();
        if smoke() {
            preset.nnz /= 4;
        }
        let t = preset.build();
        let factors = random_factors(&t.dims, rank, 1);
        let blco = BlcoEngine::new(
            BlcoTensor::from_coo_with(&t, preset.blco_config()),
            profile.clone(),
        );
        let mm = MmCsfEngine::new(&t);
        let (mut blco_vol, mut blco_tp) = (0.0f64, Vec::new());
        for mode in 0..t.order() {
            let m = measure(&blco, mode, &factors, t.dims[mode] as usize, threads, reps, &profile);
            blco_vol += m.volume_gb();
            blco_tp.push(m.model_tp_tbps());
            tbl.row(&[
                name.to_string(),
                "BLCO".into(),
                (mode + 1).to_string(),
                format!("{:.3}", m.volume_gb()),
                format!("{:.3}", m.model_tp_tbps()),
                format!("{:.2}", m.snap.coalesced_frac()),
            ]);
        }
        let (mut mm_vol, mut mm_tp) = (0.0f64, Vec::new());
        for mode in 0..t.order() {
            let m = measure(&mm, mode, &factors, t.dims[mode] as usize, threads, reps, &profile);
            mm_vol += m.volume_gb();
            mm_tp.push(m.model_tp_tbps());
            tbl.row(&[
                name.to_string(),
                "MM-CSF".into(),
                (mode + 1).to_string(),
                format!("{:.3}", m.volume_gb()),
                format!("{:.3}", m.model_tp_tbps()),
                format!("{:.2}", m.snap.coalesced_frac()),
            ]);
        }
        json.metric(&format!("{name}_blco_vol_gb"), blco_vol);
        json.metric(&format!("{name}_blco_tp_tbps_geomean"), geomean(&blco_tp));
        json.metric(&format!("{name}_mmcsf_vol_gb"), mm_vol);
        json.metric(&format!("{name}_mmcsf_tp_tbps_geomean"), geomean(&mm_tp));
        println!();
    }
    json.flush();
    println!(
        "(paper: MM-CSF lower Vol in most cases but lower TP and large \
         per-mode swings; BLCO higher Vol, higher + steadier TP)"
    );
}
