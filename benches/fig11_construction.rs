//! Figure 11: format construction/generation cost — BLCO vs GenTen-style
//! (COO sort per mode ≈ F-COO single copy), MM-CSF and the CPU-side ALTO
//! baseline — plus the number of all-mode MTTKRP iterations needed to
//! amortize construction (paper: ~12 for BLCO, up to 10× more for others).
//!
//!     cargo bench --bench fig11_construction

use blco::bench::{banner, bench_reps, measure, smoke, total_seconds, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::format::fcoo::FCoo;
use blco::format::mmcsf::MmCsf;
use blco::linear::alto::Encoding;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::datasets;
use blco::util::pool::default_threads;
use std::time::Instant;

/// ALTO construction = linearize + sort (no re-encode/block/batch).
fn alto_construct(t: &blco::tensor::coo::CooTensor) -> f64 {
    let w = Instant::now();
    let enc = Encoding::new(&t.dims);
    let mut idx: Vec<u128> = (0..t.nnz())
        .map(|e| {
            let c = t.coord(e);
            enc.encode(&c)
        })
        .collect();
    idx.sort_unstable();
    std::hint::black_box(&idx);
    w.elapsed().as_secs_f64()
}

fn main() {
    banner("Figure 11", "format construction cost (seconds, lower is better)");
    let threads = default_threads();
    let reps = bench_reps();
    let profile = Profile::a100();
    let filter: Option<Vec<String>> = std::env::var("BLCO_BENCH_PRESETS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());

    let tbl = Table::new(&[10, 10, 10, 10, 10, 14]);
    tbl.header(&["dataset", "BLCO", "F-COO", "MM-CSF", "ALTO", "amortize(iters)"]);
    let mut json = BenchJson::new("fig11_construction");

    for mut preset in datasets::in_memory() {
        if let Some(f) = &filter {
            if !f.iter().any(|x| x == preset.name) {
                continue;
            }
        }
        if smoke() {
            if !matches!(preset.name, "nips" | "uber") {
                continue;
            }
            preset.nnz /= 4;
        }
        let t = preset.build();

        let w = Instant::now();
        let blco = BlcoTensor::from_coo_with(&t, preset.blco_config());
        let blco_s = w.elapsed().as_secs_f64();

        let w = Instant::now();
        let _f = FCoo::from_coo(&t, 256);
        let fcoo_s = w.elapsed().as_secs_f64();

        let w = Instant::now();
        let _m = MmCsf::from_coo(&t);
        let mm_s = w.elapsed().as_secs_f64();

        let alto_s = alto_construct(&t);

        // amortization: construction / one all-mode BLCO MTTKRP (modelled)
        let factors = random_factors(&t.dims, 32, 1);
        let eng = BlcoEngine::new(blco, profile.clone());
        let ms: Vec<_> = (0..t.order())
            .map(|m| measure(&eng, m, &factors, t.dims[m] as usize, threads, reps, &profile))
            .collect();
        let (all_mode_wall, _) = total_seconds(&ms);
        let amortize = blco_s / all_mode_wall.max(1e-9);

        tbl.row(&[
            preset.name.to_string(),
            format!("{blco_s:.3}"),
            format!("{fcoo_s:.3}"),
            format!("{mm_s:.3}"),
            format!("{alto_s:.3}"),
            format!("{amortize:.1}"),
        ]);
        json.metric(&format!("{}_blco_construct_s", preset.name), blco_s);
        json.metric(
            &format!("{}_construct_mnnz_per_s", preset.name),
            t.nnz() as f64 / blco_s.max(1e-9) / 1e6,
        );
        json.metric(&format!("{}_amortize_iters", preset.name), amortize);
    }
    println!(
        "\n(paper: BLCO up to 13.6x cheaper to build than MM-CSF; ~12 \
         all-mode iterations to amortize on the A100)"
    );
    json.flush();
}
