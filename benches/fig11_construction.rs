//! Figure 11: format construction/generation cost — BLCO vs GenTen-style
//! (COO sort per mode ≈ F-COO single copy), MM-CSF and the CPU-side ALTO
//! baseline — plus the number of all-mode MTTKRP iterations needed to
//! amortize construction (paper: ~12 for BLCO, up to 10× more for others).
//!
//!     cargo bench --bench fig11_construction

use blco::bench::{banner, bench_reps, measure, smoke, total_seconds, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::format::fcoo::FCoo;
use blco::format::mmcsf::MmCsf;
use blco::linear::alto::Encoding;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::coo::CooTensor;
use blco::tensor::ooc::{build_uniform, BuildOptions};
use blco::tensor::{datasets, io, synth};
use blco::util::pool::{default_threads, ExecBackend};
use std::time::Instant;

/// ALTO construction = linearize + sort (no re-encode/block/batch).
fn alto_construct(t: &blco::tensor::coo::CooTensor) -> f64 {
    let w = Instant::now();
    let enc = Encoding::new(&t.dims);
    let mut idx: Vec<u128> = (0..t.nnz())
        .map(|e| {
            let c = t.coord(e);
            enc.encode(&c)
        })
        .collect();
    idx.sort_unstable();
    std::hint::black_box(&idx);
    w.elapsed().as_secs_f64()
}

fn main() {
    banner("Figure 11", "format construction cost (seconds, lower is better)");
    let threads = default_threads();
    let reps = bench_reps();
    let profile = Profile::a100();
    let filter: Option<Vec<String>> = std::env::var("BLCO_BENCH_PRESETS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());

    let tbl = Table::new(&[10, 10, 10, 10, 10, 14]);
    tbl.header(&["dataset", "BLCO", "F-COO", "MM-CSF", "ALTO", "amortize(iters)"]);
    let mut json = BenchJson::new("fig11_construction");

    for mut preset in datasets::in_memory() {
        if let Some(f) = &filter {
            if !f.iter().any(|x| x == preset.name) {
                continue;
            }
        }
        if smoke() {
            if !matches!(preset.name, "nips" | "uber") {
                continue;
            }
            preset.nnz /= 4;
        }
        let t = preset.build();

        let w = Instant::now();
        let blco = BlcoTensor::from_coo_with(&t, preset.blco_config());
        let blco_s = w.elapsed().as_secs_f64();

        let w = Instant::now();
        let _f = FCoo::from_coo(&t, 256);
        let fcoo_s = w.elapsed().as_secs_f64();

        let w = Instant::now();
        let _m = MmCsf::from_coo(&t);
        let mm_s = w.elapsed().as_secs_f64();

        let alto_s = alto_construct(&t);

        // amortization: construction / one all-mode BLCO MTTKRP (modelled)
        let factors = random_factors(&t.dims, 32, 1);
        let eng = BlcoEngine::new(blco, profile.clone());
        let ms: Vec<_> = (0..t.order())
            .map(|m| measure(&eng, m, &factors, t.dims[m] as usize, threads, reps, &profile))
            .collect();
        let (all_mode_wall, _) = total_seconds(&ms);
        let amortize = blco_s / all_mode_wall.max(1e-9);

        tbl.row(&[
            preset.name.to_string(),
            format!("{blco_s:.3}"),
            format!("{fcoo_s:.3}"),
            format!("{mm_s:.3}"),
            format!("{alto_s:.3}"),
            format!("{amortize:.1}"),
        ]);
        json.metric(&format!("{}_blco_construct_s", preset.name), blco_s);
        json.metric(
            &format!("{}_construct_mnnz_per_s", preset.name),
            t.nnz() as f64 / blco_s.max(1e-9) / 1e6,
        );
        json.metric(&format!("{}_amortize_iters", preset.name), amortize);
    }
    println!(
        "\n(paper: BLCO up to 13.6x cheaper to build than MM-CSF; ~12 \
         all-mode iterations to amortize on the A100)"
    );

    ooc_leg(&mut json);
    json.flush();
}

/// The pre-PR8 `.tns` parser, kept verbatim as a throughput baseline: one
/// heap `String` per line plus a `Vec<&str>` token collect per line — the
/// allocation pattern the reusable-buffer parser replaces.
fn parse_tns_lines_baseline(path: &std::path::Path) -> CooTensor {
    use std::io::BufRead;
    let r = std::io::BufReader::new(std::fs::File::open(path).unwrap());
    let mut coords: Vec<Vec<u32>> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    let mut maxima: Vec<u64> = Vec::new();
    for line in r.lines() {
        let line = line.unwrap();
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') || s.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = s.split_whitespace().collect();
        let order = toks.len() - 1;
        if coords.is_empty() {
            coords = vec![Vec::new(); order];
            maxima = vec![0u64; order];
        }
        for (n, tok) in toks[..order].iter().enumerate() {
            let idx: u64 = tok.parse().unwrap();
            maxima[n] = maxima[n].max(idx);
            coords[n].push((idx - 1) as u32);
        }
        vals.push(toks[order].parse().unwrap());
    }
    CooTensor { dims: maxima, coords, vals }
}

/// PR8 leg: `.tns` parse throughput (per-line-alloc baseline vs the
/// reusable-buffer chunked parser) and the external-memory build under a
/// tight budget.
fn ooc_leg(json: &mut BenchJson) {
    let dims = [4000u64, 3000, 2000]; // sparse: generator dedup stays off
    let nnz = if smoke() { 60_000 } else { 1_000_000 };
    let seed = 11;
    let t = synth::uniform(&dims, nnz, seed);
    let mut tns = std::env::temp_dir();
    tns.push(format!("blco_fig11_{}.tns", std::process::id()));
    io::write_tns(&tns, &t).unwrap();

    let w = Instant::now();
    let legacy = parse_tns_lines_baseline(&tns);
    let lines_s = w.elapsed().as_secs_f64();
    let w = Instant::now();
    let fresh = io::read_tns(&tns, None).unwrap();
    let chunked_s = w.elapsed().as_secs_f64();
    assert_eq!(legacy.vals, fresh.vals, "parser baseline disagrees");
    let lines_tput = nnz as f64 / lines_s.max(1e-9) / 1e6;
    let chunked_tput = nnz as f64 / chunked_s.max(1e-9) / 1e6;

    let budget = 4usize << 20;
    let mut out = std::env::temp_dir();
    out.push(format!("blco_fig11_{}.blco", std::process::id()));
    let opts = BuildOptions {
        // the default 2^19-nnz open block alone would outgrow the 4 MiB
        // budget; cap it so the budget governs the whole pipeline
        config: blco::format::blco::BlcoConfig {
            max_block_nnz: 1 << 15,
            ..Default::default()
        },
        backend: ExecBackend::from_threads(default_threads()),
        mem_budget_bytes: Some(budget),
        ..Default::default()
    };
    let (_, stats) = build_uniform(&dims, nnz, seed, &out, &opts).unwrap();
    assert!(stats.peak_bytes <= budget, "bench build blew its budget");

    println!("\nout-of-core construction ({nnz} nnz, {budget} B budget):");
    println!(
        "  .tns parse   {lines_tput:.2} -> {chunked_tput:.2} Mnnz/s \
         ({:+.0}% vs per-line allocs)",
        (chunked_tput / lines_tput.max(1e-9) - 1.0) * 100.0
    );
    println!(
        "  streamed     {:.2} Mnnz/s, peak {:.1} KiB, {} runs x {} nnz",
        stats.mnnz_per_s(),
        stats.peak_bytes as f64 / 1024.0,
        stats.runs,
        stats.chunk_nnz
    );
    json.metric("tns_parse_lines_mnnz_per_s", lines_tput);
    json.metric("tns_parse_chunked_mnnz_per_s", chunked_tput);
    json.metric("ooc_build_mnnz_per_s", stats.mnnz_per_s());
    json.metric("ooc_build_peak_bytes", stats.peak_bytes as f64);
    std::fs::remove_file(&tns).ok();
    std::fs::remove_file(&out).ok();
}
