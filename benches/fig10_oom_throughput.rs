//! Figure 10: memory throughput of BLCO MTTKRP on the out-of-memory
//! tensors (Amazon, Patents, Reddit) per mode on the A100 profile — overall
//! (with host↔device transfers) vs in-memory (compute only). The paper
//! finds perfect overlap but link-bound overall throughput (57–75% of the
//! device bandwidth is unreachable; the interconnect dominates).
//!
//!     cargo bench --bench fig10_oom_throughput
//!
//! Env: BLCO_BENCH_OOM_SCALE=N divides preset nnz by N (default 4 — keeps
//! the bench minutes-fast; set 1 for the full presets).

use blco::bench::{banner, Table};
use blco::coordinator::streamer::stream_mttkrp;
use blco::device::model::throughput_tbps;
use blco::device::{Counters, Profile};
use blco::format::blco::BlcoTensor;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::datasets;
use blco::util::pool::default_threads;

fn main() {
    banner("Figure 10", "OOM streaming throughput, overall vs in-memory (a100)");
    let profile = Profile::a100();
    let threads = default_threads();
    let rank = 32;
    let scale: usize = std::env::var("BLCO_BENCH_OOM_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    let tbl = Table::new(&[10, 6, 8, 14, 14, 12, 12]);
    tbl.header(&[
        "dataset", "mode", "batches", "overall TB/s", "in-mem TB/s", "link busy", "wall(s)",
    ]);

    for mut preset in datasets::out_of_memory() {
        preset.nnz /= scale;
        println!("building {} ({} nnz) ...", preset.name, preset.nnz);
        let t = preset.build();
        // scale the device memory with the tensor so the OOM classification
        // and batch counts survive BLCO_BENCH_OOM_SCALE
        let mut prof = profile.clone();
        prof.dev_mem_bytes /= scale;
        let eng = BlcoEngine::new(
            BlcoTensor::from_coo_with(&t, preset.blco_config()),
            prof,
        );
        for mode in 0..t.order() {
            let counters = Counters::new();
            let mut out = Matrix::zeros(t.dims[mode] as usize, rank);
            let factors = random_factors(&t.dims, rank, 1);
            let rep = stream_mttkrp(&eng, mode, &factors, &mut out, threads, &counters);
            let vol = counters.snapshot().volume_bytes();
            tbl.row(&[
                preset.name.to_string(),
                (mode + 1).to_string(),
                rep.batches.len().to_string(),
                format!("{:.3}", throughput_tbps(vol, rep.overall_s)),
                format!("{:.3}", throughput_tbps(vol, rep.compute_s.max(1e-12))),
                format!("{:.0}%", rep.overlap_efficiency() * 100.0),
                format!("{:.2}", rep.wall_s),
            ]);
        }
    }
    println!(
        "\n(paper: in-memory throughput on par with Table 3; overall limited \
         by the interconnect to well below device bandwidth)"
    );
}
