//! Figure 10: memory throughput of BLCO MTTKRP on the out-of-memory
//! tensors (Amazon, Patents, Reddit) per mode on the A100 profile — overall
//! (with host↔device transfers) vs in-memory (compute only). The paper
//! finds perfect overlap but link-bound overall throughput (57–75% of the
//! device bandwidth is unreachable; the interconnect dominates).
//!
//!     cargo bench --bench fig10_oom_throughput
//!
//! Env: BLCO_BENCH_OOM_SCALE=N divides preset nnz by N (default 4 — keeps
//! the bench minutes-fast; set 1 for the full presets).

use blco::bench::{banner, smoke, BenchJson, Table};
use blco::coordinator::engine::MttkrpEngine;
use blco::coordinator::streamer::StreamReport;
use blco::cpals::CpAlsOptions;
use blco::device::model::throughput_tbps;
use blco::device::{Counters, LinkTopology, Profile};
use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::format::store::{BlcoStore, BlcoStoreReader, Codec};
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::{datasets, synth};
use blco::util::pool::default_threads;
use blco::StreamRequest;

/// Single-device streamed MTTKRP through the request front door.
fn stream(
    eng: &BlcoEngine,
    mode: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> StreamReport {
    StreamRequest::new(eng, mode)
        .job(factors)
        .devices(1)
        .threads(threads)
        .counters(counters)
        .run(std::slice::from_mut(out))
        .expect("valid stream request")
        .into_streamed()
        .expect("one device streams")
}

fn main() {
    banner("Figure 10", "OOM streaming throughput, overall vs in-memory (a100)");
    let profile = Profile::a100();
    let threads = default_threads();
    let rank = 32;
    // smoke mode shrinks the presets 64x (seconds-fast) unless the env
    // override asks for something specific
    let scale: usize = std::env::var("BLCO_BENCH_OOM_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke() { 64 } else { 4 });
    let mut json = BenchJson::new("fig10_oom_throughput");

    let tbl = Table::new(&[10, 6, 8, 14, 14, 12, 12]);
    tbl.header(&[
        "dataset", "mode", "batches", "overall TB/s", "in-mem TB/s", "link busy", "wall(s)",
    ]);

    // rows for the device-count sweep (Figure 10b), collected while each
    // preset's tensor is alive so nothing is built twice
    let mut sweep_rows: Vec<Vec<String>> = Vec::new();

    for mut preset in datasets::out_of_memory() {
        preset.nnz /= scale;
        println!("building {} ({} nnz) ...", preset.name, preset.nnz);
        let t = preset.build();
        // scale the device memory with the tensor so the OOM classification
        // and batch counts survive BLCO_BENCH_OOM_SCALE
        let mut prof = profile.clone();
        prof.dev_mem_bytes /= scale;
        let eng = BlcoEngine::new(
            BlcoTensor::from_coo_with(&t, preset.blco_config()),
            prof,
        );
        // (overall_s, volume, transfer_s) of the mode-0 row — reused below
        // as the sweep's D = 1 anchor (same profile, factors and batches;
        // the degenerate-parity test proves the reports are identical)
        let mut mode0 = (0.0f64, 0u64, 0.0f64);
        for mode in 0..t.order() {
            let counters = Counters::new();
            let mut out = Matrix::zeros(t.dims[mode] as usize, rank);
            let factors = random_factors(&t.dims, rank, 1);
            let rep = stream(&eng, mode, &factors, &mut out, threads, &counters);
            let vol = counters.snapshot().volume_bytes();
            if mode == 0 {
                mode0 = (rep.overall_s, vol, rep.transfer_s);
                json.metric(
                    &format!("{}_mode0_overall_tbps", preset.name),
                    throughput_tbps(vol, rep.overall_s),
                );
                json.metric(
                    &format!("{}_mode0_inmem_tbps", preset.name),
                    throughput_tbps(vol, rep.compute_s.max(1e-12)),
                );
            }
            tbl.row(&[
                preset.name.to_string(),
                (mode + 1).to_string(),
                rep.batches.len().to_string(),
                format!("{:.3}", throughput_tbps(vol, rep.overall_s)),
                format!("{:.3}", throughput_tbps(vol, rep.compute_s.max(1e-12))),
                format!("{:.0}%", rep.overlap_efficiency() * 100.0),
                format!("{:.2}", rep.wall_s),
            ]);
        }

        // ---- device-count sweep (mode 0), sharing the BLCO tensor by Arc.
        // D = 1 is identical under both topologies (one device, one link)
        // and to the mode-0 row above, so it is not re-run.
        let (base_overall, vol1, transfer1) = mode0;
        let occ1 = if base_overall > 0.0 {
            (transfer1 / base_overall).min(1.0)
        } else {
            0.0
        };
        let factors = random_factors(&t.dims, rank, 1);
        for links in [LinkTopology::Shared, LinkTopology::Dedicated] {
            sweep_rows.push(vec![
                preset.name.to_string(),
                format!("{links:?}").to_lowercase(),
                "1".to_string(),
                format!("{:.3}", throughput_tbps(vol1, base_overall)),
                "1.00x".to_string(),
                "1.000".to_string(), // one device: perfectly "balanced"
                format!("{:.0}%", occ1 * 100.0),
            ]);
            for d in [2usize, 4] {
                let mut prof = profile.clone().with_devices(d).with_links(links);
                prof.dev_mem_bytes /= scale;
                let ceng = eng.share_with_profile(prof.clone());
                let counters = Counters::new();
                let mut out = Matrix::zeros(t.dims[0] as usize, rank);
                let rep = StreamRequest::new(&ceng, 0)
                    .job(&factors)
                    .threads(threads)
                    .counters(&counters)
                    .run(std::slice::from_mut(&mut out))
                    .expect("valid cluster request")
                    .into_clustered()
                    .expect("multi-device profile shards");
                let vol = counters.snapshot().volume_bytes();
                json.metric(
                    &format!(
                        "{}_d{}_{}_makespan_s",
                        preset.name,
                        d,
                        format!("{links:?}").to_lowercase()
                    ),
                    rep.overall_s,
                );
                sweep_rows.push(vec![
                    preset.name.to_string(),
                    format!("{links:?}").to_lowercase(),
                    d.to_string(),
                    format!("{:.3}", throughput_tbps(vol, rep.overall_s)),
                    format!("{:.2}x", base_overall / rep.overall_s.max(1e-12)),
                    format!("{:.3}", rep.imbalance()),
                    format!("{:.0}%", rep.link_occupancy(&prof) * 100.0),
                ]);
            }
        }
    }
    println!(
        "\n(paper: in-memory throughput on par with Table 3; overall limited \
         by the interconnect to well below device bandwidth)"
    );

    // ---- device-count sweep results: the scaling axis past the paper's
    // single-GPU regime.
    banner(
        "Figure 10b (extension)",
        "sharded OOM streaming, device-count sweep (a100, mode 0)",
    );
    let tbl = Table::new(&[10, 10, 4, 14, 10, 10, 12]);
    tbl.header(&[
        "dataset", "links", "D", "overall TB/s", "speedup", "imbalance", "link busy",
    ]);
    for row in &sweep_rows {
        tbl.row(row);
    }
    println!(
        "\n(shared links: sharding only helps until the one host link \
         saturates; dedicated links: near-linear streaming scaling, with \
         the tree merge as the new fixed cost)"
    );

    // ---- cached-vs-cold ALS sweep: the decomposition loop issues the
    // same (mode, rank) MTTKRP every iteration, so the facade memoizes one
    // StreamSchedule per mode. The cold row replans on every call — the
    // pre-cache behavior — and the plans-built column makes the
    // difference observable (modes vs modes × iterations).
    banner(
        "ALS schedule cache (extension)",
        "cached vs cold out-of-memory planning across a CP-ALS run",
    );
    let (als_dims, als_nnz, als_iters): (&[u64], usize, usize) = if smoke() {
        (&[1_200, 800, 600], 80_000, 3)
    } else {
        (&[3_000, 2_000, 1_500], 300_000, 5)
    };
    let t = synth::fiber_clustered(als_dims, als_nnz, 2, 0.7, 21);
    let cfg = BlcoConfig { max_block_nnz: 1 << 14, ..Default::default() };
    let opts = CpAlsOptions { rank: 16, max_iters: als_iters, tol: 0.0, threads, seed: 3 };
    let tbl = Table::new(&[8, 12, 10, 12, 12, 12]);
    tbl.header(&[
        "plans", "built", "reused", "mttkrp(s)", "total(s)", "OOM MiB",
    ]);
    for cached in [true, false] {
        let engine = MttkrpEngine::from_coo_with(&t, Profile::tiny(1 << 20), cfg)
            .with_threads(threads)
            .with_schedule_caching(cached);
        assert!(engine.is_oom(opts.rank), "sweep tensor must stream");
        let rep = engine.cp_als(opts);
        tbl.row(&[
            if cached { "cached" } else { "cold" }.to_string(),
            rep.schedule.built.to_string(),
            rep.schedule.hits.to_string(),
            format!("{:.3}", rep.mttkrp_seconds),
            format!("{:.3}", rep.total_seconds),
            format!("{:.1}", rep.stream.bytes as f64 / (1 << 20) as f64),
        ]);
        let label = if cached { "cached" } else { "cold" };
        json.metric(&format!("als_{label}_plans_built"), rep.schedule.built as f64);
        json.metric(&format!("als_{label}_mttkrp_s"), rep.mttkrp_seconds);
    }
    println!(
        "\n(cached: one plan per mode, reused every iteration; cold: \
         modes × iterations plans — the planning overhead the schedule \
         cache removes from the ALS hot loop)"
    );

    // ---- disk-backed leg: the same streamed MTTKRP with the block
    // payload on disk behind a bounded cache, batch b+1 prefetched while
    // batch b computes. Budget = 2x the largest batch, so current +
    // lookahead always fit and every prefetch lands before demand.
    banner(
        "OOM prefetch (extension)",
        "disk-resident streaming with the async block prefetcher",
    );
    let (pf_dims, pf_nnz): (&[u64], usize) = if smoke() {
        (&[1_200, 800, 600], 80_000)
    } else {
        (&[3_000, 2_000, 1_500], 400_000)
    };
    let t = synth::fiber_clustered(pf_dims, pf_nnz, 2, 0.7, 33);
    let b = BlcoTensor::from_coo_with(
        &t,
        BlcoConfig { max_block_nnz: 1 << 14, ..Default::default() },
    );
    let dir = std::env::temp_dir()
        .join(format!("blco_fig10_prefetch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create bench temp dir");
    let path = dir.join("tensor.blco");
    // container v2 with per-block delta+varint compression: the disk
    // leg measures what compression buys the out-of-memory tier
    let summary =
        BlcoStore::write_with(&b, &path, Codec::DeltaVarint).expect("write store");
    {
        let reader = BlcoStoreReader::open(&path).expect("open store");
        json.metric("store_compress_ratio", reader.compression_ratio());
        json.metric("store_read_amp", reader.read_amplification());
        json.metric("oom_disk_bytes_compressed", summary.stored_bytes as f64);
        println!(
            "container v2: {} raw MiB -> {} stored MiB ({:.2}x), read amp {:.2}",
            reader.raw_payload_bytes() / (1 << 20),
            reader.stored_payload_bytes() / (1 << 20),
            reader.compression_ratio(),
            reader.read_amplification(),
        );
    }
    let probe = BlcoEngine::from_store_reader(
        BlcoStoreReader::open(&path).expect("open store"),
        profile.clone(),
    );
    let max_batch = (0..probe.src.num_batches())
        .map(|i| probe.src.batch_bytes(i))
        .max()
        .unwrap_or(0);
    let batches = probe.src.num_batches();
    drop(probe);
    let eng = BlcoEngine::from_store_reader(
        BlcoStoreReader::open_with_budget(&path, 2 * max_batch)
            .expect("reopen store"),
        profile.clone(),
    );
    let factors = random_factors(&t.dims, rank, 1);
    let counters = Counters::new();
    let mut out = Matrix::zeros(t.dims[0] as usize, rank);
    let rep = stream(&eng, 0, &factors, &mut out, threads, &counters);
    let cache = eng.src.reader().expect("disk engine has a reader").cache_stats();
    std::fs::remove_dir_all(&dir).ok();
    assert!(
        cache.peak_resident_bytes <= cache.budget_bytes,
        "prefetch overran the cache budget: peak {} > budget {}",
        cache.peak_resident_bytes,
        cache.budget_bytes
    );
    assert!(
        cache.prefetch_hits > 0,
        "budget 2x max batch but no demand lookup hit a prefetched block"
    );
    let tbl = Table::new(&[8, 10, 14, 14, 12, 12]);
    tbl.header(&[
        "batches", "wall(s)", "prefetch hits", "wasted", "peak KiB", "budget KiB",
    ]);
    tbl.row(&[
        batches.to_string(),
        format!("{:.3}", rep.wall_s),
        cache.prefetch_hits.to_string(),
        cache.prefetch_wasted.to_string(),
        format!("{:.1}", cache.peak_resident_bytes as f64 / 1024.0),
        format!("{:.1}", cache.budget_bytes as f64 / 1024.0),
    ]);
    json.metric("oom_prefetch_hits_count", cache.prefetch_hits as f64);
    json.metric("oom_prefetch_wasted_count", cache.prefetch_wasted as f64);
    json.metric("oom_prefetch_wall_s", rep.wall_s);
    println!(
        "\n(the prefetch thread stages batch b+1's blocks off disk while \
         batch b computes; hits = demand lookups served from staged \
         blocks, bounded by the same host_mem_bytes cache budget)"
    );
    json.flush();
}
