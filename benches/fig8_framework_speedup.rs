//! Figure 8: all-mode MTTKRP speedup over MM-CSF for BLCO, GenTen (COO +
//! atomics engine, its closest analogue here — see DESIGN.md §3) and F-COO,
//! on each simulated device, rank 32, with the geometric mean. The paper
//! reports BLCO at 2.12–2.6× geomean over MM-CSF.
//!
//!     cargo bench --bench fig8_framework_speedup
//!
//! Env: BLCO_BENCH_PRESETS=uber,nell2 to restrict, BLCO_BENCH_REPS=N.

use blco::bench::{banner, bench_reps, geomean, measure, smoke, total_seconds, BenchJson, Table};
use blco::device::Profile;
use blco::format::blco::BlcoTensor;
use blco::format::fcoo::FCoo;
use blco::mttkrp::blco::BlcoEngine;
use blco::mttkrp::coo::CooAtomicEngine;
use blco::mttkrp::csf::MmCsfEngine;
use blco::mttkrp::fcoo::FCooEngine;
use blco::mttkrp::oracle::random_factors;
use blco::mttkrp::Mttkrp;
use blco::tensor::datasets;
use blco::util::pool::default_threads;

fn preset_filter() -> Option<Vec<String>> {
    std::env::var("BLCO_BENCH_PRESETS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
}

fn main() {
    banner("Figure 8", "all-mode MTTKRP speedup vs MM-CSF (higher is better)");
    let threads = default_threads();
    let reps = bench_reps();
    let rank = 32;
    let filter = preset_filter();
    let mut json = BenchJson::new("fig8_framework_speedup");

    let profiles = if smoke() { vec![Profile::a100()] } else { Profile::all() };
    for profile in profiles {
        println!("\n--- device: {} ---", profile.name);
        let tbl = Table::new(&[10, 10, 10, 10, 12]);
        tbl.header(&["dataset", "BLCO", "GenTen", "F-COO", "MM-CSF(ms)"]);
        let (mut g_blco, mut g_gen, mut g_fcoo) = (vec![], vec![], vec![]);

        for mut preset in datasets::in_memory() {
            if let Some(f) = &filter {
                if !f.iter().any(|x| x == preset.name) {
                    continue;
                }
            }
            if smoke() {
                if !matches!(preset.name, "nips" | "uber") {
                    continue;
                }
                preset.nnz /= 4;
            }
            let t = preset.build();
            let factors = random_factors(&t.dims, rank, 1);

            let all_modes = |eng: &dyn Mttkrp| -> f64 {
                let ms: Vec<_> = (0..t.order())
                    .map(|m| {
                        measure(eng, m, &factors, t.dims[m] as usize, threads, reps, &profile)
                    })
                    .collect();
                total_seconds(&ms).1 // modelled device seconds
            };

            let mm = all_modes(&MmCsfEngine::new(&t));
            let blco = all_modes(
                &BlcoEngine::new(
                    BlcoTensor::from_coo_with(&t, preset.blco_config()),
                    profile.clone(),
                ),
            );
            let gen = all_modes(&CooAtomicEngine::new(t.clone()));
            let fcoo = all_modes(&FCooEngine::new(FCoo::from_coo(&t, 256)));

            g_blco.push(mm / blco);
            g_gen.push(mm / gen);
            g_fcoo.push(mm / fcoo);
            tbl.row(&[
                preset.name.to_string(),
                format!("{:.2}x", mm / blco),
                format!("{:.2}x", mm / gen),
                format!("{:.2}x", mm / fcoo),
                format!("{:.2}", mm * 1e3),
            ]);
        }
        tbl.row(&[
            "geomean".into(),
            format!("{:.2}x", geomean(&g_blco)),
            format!("{:.2}x", geomean(&g_gen)),
            format!("{:.2}x", geomean(&g_fcoo)),
            "-".into(),
        ]);
        println!("  (paper geomean for BLCO: 2.12-2.6x across devices)");
        json.metric(&format!("{}_blco_geomean_speedup", profile.name), geomean(&g_blco));
        json.metric(&format!("{}_genten_geomean_speedup", profile.name), geomean(&g_gen));
        json.metric(&format!("{}_fcoo_geomean_speedup", profile.name), geomean(&g_fcoo));
    }
    json.flush();
    println!("\n(GenTen = its GPU kernel, i.e. COO + global atomics; the CPU-style\n permutation variant is the separate `genten` engine, see the ablation bench.)");
}
