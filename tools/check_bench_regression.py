#!/usr/bin/env python3
"""Gate a bench-smoke run against a committed perf baseline.

Compares two BENCH json artifacts in the merge_bench_json.py schema
(`{"schema": 1, "records": [{"figure", "smoke", "metrics": {...}}, ...]}`)
and exits non-zero when any pinned metric regresses by more than
--max-regress-pct (default 15%).

The baseline's metric *names* are the pin set: every figure and metric in
the baseline must be present in the candidate run, so a bench that stops
emitting a metric fails the gate rather than silently shrinking coverage.
Metrics present only in the candidate are reported but never fail — new
metrics land first, get pinned when the baseline is refreshed.

A baseline value of null pins presence only (no numeric comparison). That
is how a provisional baseline is committed before trustworthy numbers
exist for the CI runner class; refresh it from a real run with:

    check_bench_regression.py BENCH_PR6.json BENCH_smoke.json --write-baseline

The improvement direction is inferred from the metric name:

  * ``_ms`` / ``_s`` / ``_vol_gb`` / ``_pct``  — lower is better
  * ``_speedup`` / ``_tbps`` / ``_tp_tbps_geomean`` / ``_over_best``
    — higher is better (regression = drop)
  * counts (``_batches``, ``_pairs``, ``_plans_built``, ``_iters``, ...)
    — structural, compared exactly (any change fails; these encode
    schedule/analysis decisions, not timing noise)

Usage: check_bench_regression.py BASELINE.json CANDIDATE.json
           [--max-regress-pct 15] [--write-baseline]
"""

import argparse
import json
import math
import sys

LOWER_IS_BETTER = ("_ms", "_s", "_vol_gb", "_pct", "_makespan_s", "_wall_ms")
# "_per_s" must be matched before LOWER_IS_BETTER's bare "_s": throughput
# metrics like ooc_build_mnnz_per_s are higher-is-better, and the suffix
# ordering in direction() is what keeps them from being misread as timings
HIGHER_IS_BETTER = ("_per_s", "_speedup", "_tbps", "_over_best")
EXACT = ("_batches", "_pairs", "_plans_built", "_iters", "_count")


def fail(msg: str) -> None:
    print(f"check_bench_regression: error: {msg}", file=sys.stderr)
    sys.exit(1)


def direction(name: str) -> str:
    """'lower', 'higher', or 'exact' for a metric name."""
    if name.endswith(EXACT):
        return "exact"
    if name.endswith(HIGHER_IS_BETTER):
        return "higher"
    if name.endswith(LOWER_IS_BETTER):
        return "lower"
    # unknown shapes are treated as timing-like so a rename cannot turn a
    # real regression into a free pass
    return "lower"


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if doc.get("schema") != 1:
        fail(f"{path}: unsupported schema {doc.get('schema')!r}")
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        fail(f"{path}: no records")
    by_figure = {}
    for rec in records:
        figure = rec.get("figure")
        metrics = rec.get("metrics")
        if not isinstance(figure, str) or not isinstance(metrics, dict):
            fail(f"{path}: malformed record {rec!r}")
        if figure in by_figure:
            fail(f"{path}: duplicate figure {figure!r}")
        by_figure[figure] = metrics
    return by_figure


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="pinned baseline (e.g. BENCH_PR6.json)")
    ap.add_argument("candidate", help="fresh smoke artifact (BENCH_smoke.json)")
    ap.add_argument(
        "--max-regress-pct",
        type=float,
        default=15.0,
        help="fail when a pinned metric regresses by more than this (default 15)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="on success, overwrite BASELINE with CANDIDATE's numbers "
        "(restricted to the pinned metric set)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    cand = load(args.candidate)

    failures = []
    compared = presence_only = 0

    for figure, metrics in sorted(base.items()):
        if figure not in cand:
            failures.append(f"{figure}: figure missing from candidate run")
            continue
        got = cand[figure]
        for name, pinned in sorted(metrics.items()):
            if name not in got:
                failures.append(f"{figure}.{name}: metric missing from candidate run")
                continue
            value = got[name]
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                failures.append(f"{figure}.{name}: candidate value {value!r} not numeric")
                continue
            if pinned is None:
                presence_only += 1
                continue  # provisional pin: presence is the whole contract
            if not isinstance(pinned, (int, float)) or isinstance(pinned, bool):
                fail(f"{args.baseline}: {figure}.{name}: bad pinned value {pinned!r}")
            compared += 1
            d = direction(name)
            if d == "exact":
                if value != pinned:
                    failures.append(
                        f"{figure}.{name}: structural metric changed "
                        f"{pinned} -> {value}"
                    )
                continue
            if pinned == 0 or not math.isfinite(pinned):
                continue  # nothing sensible to scale against
            delta_pct = (value - pinned) / abs(pinned) * 100.0
            regress_pct = delta_pct if d == "lower" else -delta_pct
            if regress_pct > args.max_regress_pct:
                worse = "slower" if d == "lower" else "lower"
                failures.append(
                    f"{figure}.{name}: {pinned:.6g} -> {value:.6g} "
                    f"({regress_pct:+.1f}% {worse}, limit {args.max_regress_pct:.0f}%)"
                )

    new_metrics = sum(
        1
        for figure, metrics in cand.items()
        for name in metrics
        if name not in base.get(figure, {})
    )

    if failures:
        print(
            f"check_bench_regression: {len(failures)} failure(s) vs {args.baseline}:",
            file=sys.stderr,
        )
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)

    print(
        f"check_bench_regression: OK — {compared} metrics within "
        f"{args.max_regress_pct:.0f}%, {presence_only} provisional pins "
        f"present, {new_metrics} unpinned candidate metrics"
    )

    if args.write_baseline:
        refreshed = {
            figure: {name: cand[figure][name] for name in metrics}
            for figure, metrics in base.items()
        }
        records = [
            {"figure": figure, "smoke": True, "metrics": metrics}
            for figure, metrics in sorted(refreshed.items())
        ]
        out = {
            "schema": 1,
            "records": records,
            "figures": [r["figure"] for r in records],
            "metric_count": sum(len(r["metrics"]) for r in records),
        }
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_bench_regression: refreshed {args.baseline}")


if __name__ == "__main__":
    main()
