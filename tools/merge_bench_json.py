#!/usr/bin/env python3
"""Consolidate bench JSONL records into one validated BENCH_smoke.json.

Each bench binary (benches/fig*.rs) appends one JSON line per run to the
file named by BLCO_BENCH_JSON when it is set:

    {"figure": "fig10_oom_throughput", "smoke": true, "metrics": {...}}

This script merges those lines into a single artifact and *fails* on any
malformed record — a missing figure name, an empty metrics map, a
non-finite/null metric, or a duplicate figure — so the bench-smoke CI job
turns silent emission bugs into red builds instead of empty artifacts.

Usage: merge_bench_json.py RECORDS.jsonl [-o BENCH_smoke.json]
"""

import argparse
import json
import math
import sys


def fail(msg: str) -> None:
    print(f"merge_bench_json: error: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("records", help="JSONL stream appended by the bench binaries")
    ap.add_argument("-o", "--out", default="BENCH_smoke.json")
    ap.add_argument(
        "--expect",
        type=int,
        default=0,
        help="fail unless at least this many figure records are present",
    )
    args = ap.parse_args()

    try:
        lines = open(args.records, encoding="utf-8").read().splitlines()
    except OSError as e:
        fail(f"cannot read {args.records}: {e}")

    records = []
    seen = set()
    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"{args.records}:{lineno}: not valid JSON: {e}")
        if not isinstance(rec, dict):
            fail(f"{args.records}:{lineno}: record is not an object")
        figure = rec.get("figure")
        if not isinstance(figure, str) or not figure:
            fail(f"{args.records}:{lineno}: missing/empty 'figure'")
        if figure in seen:
            fail(f"{args.records}:{lineno}: duplicate figure {figure!r}")
        seen.add(figure)
        metrics = rec.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            fail(f"{args.records}:{lineno}: {figure}: missing/empty 'metrics'")
        for name, value in metrics.items():
            if not isinstance(name, str) or not name:
                fail(f"{args.records}:{lineno}: {figure}: bad metric name {name!r}")
            # null marks a non-finite number the bench refused to serialize
            if value is None:
                fail(f"{args.records}:{lineno}: {figure}: metric {name!r} is non-finite")
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                fail(
                    f"{args.records}:{lineno}: {figure}: metric {name!r} "
                    f"is not a number: {value!r}"
                )
            if not math.isfinite(value):
                fail(f"{args.records}:{lineno}: {figure}: metric {name!r} = {value}")
        threads = rec.get("threads", 1)
        if isinstance(threads, bool) or not isinstance(threads, int) or threads < 1:
            fail(f"{args.records}:{lineno}: {figure}: bad 'threads' {threads!r}")
        records.append(
            {
                "figure": figure,
                "smoke": bool(rec.get("smoke", False)),
                "threads": threads,
                "metrics": metrics,
            }
        )

    if not records:
        fail(f"{args.records}: no records — did the benches run with BLCO_BENCH_JSON set?")
    if args.expect and len(records) < args.expect:
        fail(f"expected >= {args.expect} figure records, found {len(records)}")

    records.sort(key=lambda r: r["figure"])
    out = {
        "schema": 1,
        "records": records,
        "figures": [r["figure"] for r in records],
        "metric_count": sum(len(r["metrics"]) for r in records),
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"merge_bench_json: wrote {args.out} "
        f"({len(records)} figures, {out['metric_count']} metrics)"
    )


if __name__ == "__main__":
    main()
